//! Parallel, cached experiment engine.
//!
//! The paper's evaluation is a grid — benchmark suite x architecture
//! variants x placement seeds (3 variants x ~30 circuits x 3 seeds).
//! [`ExperimentPlan`] describes that grid; [`Engine::run`] expands it into
//! independent jobs and executes them on a scoped-thread work queue
//! ([`crate::coordinator::parallel_indexed`]) in three phases:
//!
//! 1. **map** — one job per distinct circuit (variant-independent),
//! 2. **pack** — one job per (circuit, variant),
//! 3. **place/route** — one job per (circuit, variant, seed).
//!
//! A content-addressed [`ArtifactCache`] backs phases 1 and 2, so the
//! mapped netlist is computed once per circuit and the packing once per
//! (circuit, variant) no matter how many variants/seeds (or later plans
//! sharing the cache) consume them; seed jobs read the artifacts through
//! shared `Arc`s instead of recomputing per grid cell.
//!
//! ## Determinism contract
//!
//! Results for a given (circuit, variant, seed) are bit-identical to the
//! serial [`crate::flow::run_benchmark`] path, regardless of worker count
//! or scheduling order, because:
//!
//! * every stochastic stage derives its RNG from the seed the job carries
//!   ([`place_route_seed`] builds the placer RNG from it) — there is no
//!   shared RNG to race on,
//! * cached artifacts are immutable once published (`Arc`-shared,
//!   read-only), and recomputing them yields identical bytes, so which
//!   racing insert "wins" is unobservable,
//! * seed reduction ([`assemble_result`]) runs on the calling thread in
//!   fixed (variant, bench, seed) order.
//!
//! ## Failure isolation
//!
//! Every phase's jobs run under `catch_unwind`: a panicking job (organic
//! or injected via [`FlowOpts::faults`]) becomes a structured
//! [`FlowError`] — an upstream (map/pack/index) failure fails every
//! dependent grid cell as data, a seed-job panic fails only its seed —
//! and the rest of the plan completes untouched.  The run ends with a
//! fixed-order [`FailureSummary`] (deterministic text for any worker
//! count) and bumps the process-wide [`process_failures`] counter the
//! CLI turns into a nonzero exit code.
//!
//! ## Resident queue
//!
//! [`Engine::run`] executes a *closed* plan: the grid is fixed before the
//! first job starts.  [`PlanQueue`] is the open-ended counterpart for
//! `dd serve` (and CLI watch-mode): a resident worker pool over the same
//! [`ArtifactCache`] that accepts [`CellJob`]s — the (benchmark, variant)
//! cells an [`ExperimentPlan`] decomposes into
//! ([`ExperimentPlan::cells`]) — *while running*, dedups identical
//! submissions by content-addressed [`CellJob::submission_key`] so
//! concurrent identical jobs execute once, and tracks per-job
//! [`JobState`] with an ordered [`JobEvent`] log.  Every job runs through
//! [`run_benchmark_cached_with`] → [`crate::flow::chain_seeds`], the same
//! single definition of a cell the batch paths use, so queue results are
//! byte-identical to the batch CLI for the same submission.  Queue
//! failures stay per-job data (state + structured errors) and do *not*
//! bump [`process_failures`] — a daemon reports failures to clients, it
//! does not own the process exit code.

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::arch::device::Device;
use crate::arch::{Arch, ArchVariant};
use crate::bench_suites::Benchmark;
use crate::check::{self, CheckMode, Violation};
use crate::coordinator::parallel_indexed;
use crate::netlist::{CellKind, Netlist, NetlistIndex, PackIndex};
use crate::pack::{pack_with, PackOpts, Packing, Unrelated};
use crate::rrg::{lookahead, lookahead::Lookahead, RrGraph};
use crate::techmap::{map_circuit_with, MapOpts};

use super::diskcache::DiskCache;
use super::{
    arch_for_run, assemble_result, place_route_seed, FlowError, FlowOpts, FlowResult,
    RecoveryAction, SeedCtx, SeedMetrics,
};

/// A mapped circuit artifact: the netlist plus generation metadata.
#[derive(Debug)]
pub struct MappedCircuit {
    pub nl: Netlist,
    /// Chain-dedup hits recorded while generating the source circuit.
    pub dedup_hits: usize,
    /// Structural content hash of `nl` (the pack-cache key component).
    pub fingerprint: u64,
}

/// Dense index arenas derived from one (netlist, packing) pair — the
/// `NetlistIndex`/`PackIndex` every STA consumer reads.  Cached by the
/// [`ArtifactCache`] (keyed like packings) so seed jobs share them
/// read-only instead of rebuilding both once per seed, which is what
/// `place_route_seed`'s `--timing-route` branch used to do.
#[derive(Debug)]
pub struct IndexArenas {
    pub idx: NetlistIndex,
    pub pidx: PackIndex,
}

/// Cache hit/miss counters (observability for the perf pass).  `*_hits`
/// count in-memory hits; `*_disk_hits` count artifacts revived from the
/// persistent store; `*_misses` count actual recomputations.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub map_hits: AtomicUsize,
    pub map_disk_hits: AtomicUsize,
    pub map_misses: AtomicUsize,
    pub pack_hits: AtomicUsize,
    pub pack_disk_hits: AtomicUsize,
    pub pack_misses: AtomicUsize,
    pub index_hits: AtomicUsize,
    pub index_misses: AtomicUsize,
    pub lookahead_hits: AtomicUsize,
    pub lookahead_disk_hits: AtomicUsize,
    pub lookahead_misses: AtomicUsize,
}

impl CacheStats {
    fn bump(counter: &AtomicUsize) {
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

/// Content-addressed artifact store, shared read-only across jobs.
///
/// Mapped netlists are keyed by the benchmark's generator identity;
/// packings by (netlist content hash, architecture identity, packer
/// options) — so two benchmarks that map to structurally identical
/// netlists share one packing per variant.
#[derive(Default)]
pub struct ArtifactCache {
    mapped: Mutex<HashMap<u64, Arc<MappedCircuit>>>,
    packed: Mutex<HashMap<u64, Arc<Packing>>>,
    /// Dense index arenas per (netlist, packing) — memory-only (derived
    /// data; rebuilding is linear and the disk artifacts already capture
    /// the inputs they derive from).
    indexed: Mutex<HashMap<u64, Arc<IndexArenas>>>,
    /// Achieved post-route CPD (ps) per chained seed of the closed
    /// timing loop, keyed by [`Self::cpd_prior_key`].  This is a
    /// *provenance record* of the cross-seed place↔route feedback — the
    /// live chain flows through [`crate::flow::SeedCtx::cpd_prior_ps`];
    /// the record exists so tests and tools can audit what prior each
    /// seed ran under ([`Self::cpd_prior`] /
    /// [`Self::cpd_priors_recorded`]), not to memoize work.  Values are
    /// deterministic functions of their key, so reads can never change
    /// results.
    cpd_priors: Mutex<HashMap<u64, f64>>,
    /// Router lookahead maps per (device grid, channel width) — keyed by
    /// [`crate::rrg::lookahead::cache_key`], which hashes nothing
    /// netlist-shaped, so one map serves every benchmark routed on the
    /// same device.  Backed by the disk store when one is attached.
    lookaheads: Mutex<HashMap<u64, Arc<Lookahead>>>,
    /// Optional persistent store under the in-memory maps: a memory miss
    /// consults the disk before recomputing, and fresh computations are
    /// written back (same content-hash keys, so entries survive across
    /// processes).  `None` keeps the cache memory-only.
    disk: Option<DiskCache>,
    pub stats: CacheStats,
}

impl ArtifactCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Memory cache backed by a persistent store (the CLI roots it at
    /// [`DiskCache::default_root`], `target/dd-cache`).
    pub fn with_disk(disk: DiskCache) -> Self {
        ArtifactCache { disk: Some(disk), ..Default::default() }
    }

    /// Process-wide cache shared by the legacy `coordinator::run_jobs`
    /// path and the report harness, so repeated sweeps (e.g. Fig. 6's
    /// baseline pass followed by its DD5 pass) share mapped netlists.
    /// Bounded by the benchmark suites, which are small.
    pub fn global() -> Arc<ArtifactCache> {
        static G: OnceLock<Arc<ArtifactCache>> = OnceLock::new();
        Arc::clone(G.get_or_init(|| Arc::new(ArtifactCache::new())))
    }

    /// Process-wide cache with the default persistent store attached —
    /// what the CLI uses unless `--no-disk-cache` is passed, so repeated
    /// invocations skip the map and pack stages.
    pub fn global_disk() -> Arc<ArtifactCache> {
        static G: OnceLock<Arc<ArtifactCache>> = OnceLock::new();
        Arc::clone(G.get_or_init(|| {
            Arc::new(ArtifactCache::with_disk(DiskCache::new(DiskCache::default_root())))
        }))
    }

    /// Cache selection for the CLI's shared flags (`exp` and `flow`):
    /// `--no-disk-cache` keeps the process-wide memory cache; a
    /// `--cache-cap-mb` cap gets its own disk-backed instance (the cap is
    /// per-invocation policy, not process-global state); otherwise the
    /// process-wide disk-backed cache.
    pub fn for_cli(disk_cache: bool, cache_cap_mb: Option<u64>) -> Arc<ArtifactCache> {
        match (disk_cache, cache_cap_mb) {
            (false, _) => ArtifactCache::global(),
            (true, None) => ArtifactCache::global_disk(),
            (true, Some(mb)) => Arc::new(ArtifactCache::with_disk(
                DiskCache::with_cap_mb(DiskCache::default_root(), mb),
            )),
        }
    }

    /// Identity of a benchmark instance: name, suite, and every generator
    /// parameter that feeds the circuit (`BenchParams`' manual `Hash`
    /// impl destructures exhaustively, so new knobs can't silently alias
    /// cache entries).
    fn bench_key(b: &Benchmark) -> u64 {
        let mut h = DefaultHasher::new();
        b.name.hash(&mut h);
        b.suite.hash(&mut h);
        b.params.hash(&mut h);
        h.finish()
    }

    /// Structural content hash of a mapped netlist.
    pub fn netlist_fingerprint(nl: &Netlist) -> u64 {
        let mut h = DefaultHasher::new();
        nl.num_chains.hash(&mut h);
        nl.nets.len().hash(&mut h);
        for cell in &nl.cells {
            match cell.kind {
                CellKind::Input => 0u8.hash(&mut h),
                CellKind::Output => 1u8.hash(&mut h),
                CellKind::Lut { k, truth } => {
                    2u8.hash(&mut h);
                    k.hash(&mut h);
                    truth.hash(&mut h);
                }
                CellKind::AdderBit { chain, pos } => {
                    3u8.hash(&mut h);
                    chain.hash(&mut h);
                    pos.hash(&mut h);
                }
                CellKind::Ff => 4u8.hash(&mut h),
                CellKind::Const(v) => {
                    5u8.hash(&mut h);
                    v.hash(&mut h);
                }
            }
            cell.ins.hash(&mut h);
            cell.outs.hash(&mut h);
        }
        h.finish()
    }

    /// Pack-cache key: netlist content + the architecture facets packing
    /// actually reads (variant legality + LB organization) + packer opts.
    fn pack_key(fingerprint: u64, arch: &Arch, opts: &PackOpts) -> u64 {
        let mut h = DefaultHasher::new();
        fingerprint.hash(&mut h);
        arch.variant.hash(&mut h);
        arch.lb.alms.hash(&mut h);
        arch.lb.inputs.hash(&mut h);
        arch.lb.target_ext_pin_util.to_bits().hash(&mut h);
        (match opts.unrelated {
            Unrelated::Off => 0u8,
            Unrelated::Auto => 1u8,
            Unrelated::On => 2u8,
        })
        .hash(&mut h);
        h.finish()
    }

    /// Generate + technology-map `b`, or return the shared artifact.
    pub fn mapped(&self, b: &Benchmark) -> Arc<MappedCircuit> {
        self.mapped_with(b, 1)
    }

    /// [`Self::mapped`] with the mapper's cut enumeration sharded over
    /// `jobs` workers.  `jobs` is deliberately *not* part of the cache
    /// key: mapping is bit-identical for any worker count, so artifacts
    /// computed at different job counts are interchangeable.
    pub fn mapped_with(&self, b: &Benchmark, jobs: usize) -> Arc<MappedCircuit> {
        let key = Self::bench_key(b);
        if let Some(m) = self.mapped.lock().unwrap().get(&key) {
            CacheStats::bump(&self.stats.map_hits);
            return Arc::clone(m);
        }
        // Memory miss: revive from disk (integrity-checked) before paying
        // for a recompute.
        if let Some(d) = &self.disk {
            if let Some(m) = d.load_mapped(key) {
                CacheStats::bump(&self.stats.map_disk_hits);
                let art = Arc::new(m);
                return Arc::clone(self.mapped.lock().unwrap().entry(key).or_insert(art));
            }
        }
        // Compute outside the lock; racing workers may both compute, in
        // which case the first insert wins (identical content, so which
        // Arc survives is unobservable).
        CacheStats::bump(&self.stats.map_misses);
        let circ = b.generate();
        let nl = map_circuit_with(&circ, &MapOpts::default(), jobs);
        let fingerprint = Self::netlist_fingerprint(&nl);
        let art = Arc::new(MappedCircuit { nl, dedup_hits: circ.dedup_hits, fingerprint });
        if let Some(d) = &self.disk {
            d.store_mapped(key, &art);
        }
        Arc::clone(self.mapped.lock().unwrap().entry(key).or_insert(art))
    }

    /// Pack `mapped` for `arch`, or return the shared packing.
    pub fn packed(&self, mapped: &MappedCircuit, arch: &Arch, opts: &PackOpts) -> Arc<Packing> {
        self.packed_with(mapped, arch, opts, 1)
    }

    /// [`Self::packed`] with clustering's attraction scoring sharded over
    /// `jobs` workers (not part of the cache key — bit-identical output
    /// for any worker count).
    pub fn packed_with(
        &self,
        mapped: &MappedCircuit,
        arch: &Arch,
        opts: &PackOpts,
        jobs: usize,
    ) -> Arc<Packing> {
        let key = Self::pack_key(mapped.fingerprint, arch, opts);
        if let Some(p) = self.packed.lock().unwrap().get(&key) {
            CacheStats::bump(&self.stats.pack_hits);
            return Arc::clone(p);
        }
        if let Some(d) = &self.disk {
            if let Some(p) = d.load_packing(key) {
                CacheStats::bump(&self.stats.pack_disk_hits);
                let p = Arc::new(p);
                return Arc::clone(self.packed.lock().unwrap().entry(key).or_insert(p));
            }
        }
        CacheStats::bump(&self.stats.pack_misses);
        let p = Arc::new(pack_with(&mapped.nl, arch, opts, jobs));
        if let Some(d) = &self.disk {
            d.store_packing(key, &p);
        }
        Arc::clone(self.packed.lock().unwrap().entry(key).or_insert(p))
    }

    /// Dense index arenas for `(mapped, packing)`, or the shared
    /// instance.  Keyed like the packing (the arenas are a pure function
    /// of netlist + packing), so every seed job of a grid cell — and
    /// later plans sharing the cache — reads one read-only build.
    pub fn indexed(
        &self,
        mapped: &MappedCircuit,
        packing: &Packing,
        arch: &Arch,
        opts: &PackOpts,
    ) -> Arc<IndexArenas> {
        let key = Self::pack_key(mapped.fingerprint, arch, opts);
        if let Some(a) = self.indexed.lock().unwrap().get(&key) {
            CacheStats::bump(&self.stats.index_hits);
            return Arc::clone(a);
        }
        CacheStats::bump(&self.stats.index_misses);
        let a = Arc::new(IndexArenas {
            idx: NetlistIndex::build(&mapped.nl),
            pidx: PackIndex::build(&mapped.nl, packing),
        });
        Arc::clone(self.indexed.lock().unwrap().entry(key).or_insert(a))
    }

    /// Key of one chained seed's achieved-CPD record: netlist content,
    /// variant, every flow knob that shapes a seed result, and the *seed
    /// chain prefix* (a seed's result depends on every seed routed before
    /// it in the cell, not just its own value).
    pub fn cpd_prior_key(
        fingerprint: u64,
        arch: &Arch,
        opts: &FlowOpts,
        seed_prefix: &[u64],
    ) -> u64 {
        let mut h = DefaultHasher::new();
        fingerprint.hash(&mut h);
        arch.variant.hash(&mut h);
        opts.place_effort.to_bits().hash(&mut h);
        opts.route_timing_weights.hash(&mut h);
        opts.sta_every.hash(&mut h);
        opts.crit_alpha.to_bits().hash(&mut h);
        opts.place_crit_alpha.to_bits().hash(&mut h);
        opts.move_mix.to_bits().hash(&mut h);
        opts.use_kernel.hash(&mut h);
        // The lookahead changes routing results (sink order + heuristic),
        // so on/off records must not alias.
        opts.lookahead.hash(&mut h);
        // Recovery knobs change what a seed result *is*: escalated,
        // pops-budgeted, or fault-injected records must never alias
        // clean ones.
        opts.escalate.hash(&mut h);
        opts.route_pops_budget.hash(&mut h);
        opts.faults.hash(&mut h);
        // route_jobs is deliberately NOT keyed: results are bit-identical
        // for any worker count, so records must match across job counts.
        opts.channel_width.hash(&mut h);
        if let Some(d) = &opts.device {
            d.lb_cols.hash(&mut h);
            d.lb_rows.hash(&mut h);
            d.io_per_tile.hash(&mut h);
        }
        (match opts.unrelated {
            Unrelated::Off => 0u8,
            Unrelated::Auto => 1u8,
            Unrelated::On => 2u8,
        })
        .hash(&mut h);
        seed_prefix.hash(&mut h);
        h.finish()
    }

    /// Router lookahead map for `(device, arch)`, or the shared instance
    /// — memo, then disk (integrity-checked), then compute-and-store.
    /// The compute path goes through the process-global memo
    /// ([`crate::rrg::lookahead::shared`]) so even caches without a disk
    /// store never build the same map twice in one process.
    pub fn lookahead(&self, device: &Device, arch: &Arch) -> Arc<Lookahead> {
        let w = device.width() as usize;
        let h = device.height() as usize;
        let tracks = (arch.routing.channel_width as usize).max(1);
        let key = lookahead::cache_key(w, h, tracks);
        if let Some(m) = self.lookaheads.lock().unwrap().get(&key) {
            CacheStats::bump(&self.stats.lookahead_hits);
            return Arc::clone(m);
        }
        if let Some(d) = &self.disk {
            if let Some(la) = d.load_lookahead(key, w, h, tracks) {
                CacheStats::bump(&self.stats.lookahead_disk_hits);
                let la = Arc::new(la);
                return Arc::clone(self.lookaheads.lock().unwrap().entry(key).or_insert(la));
            }
        }
        CacheStats::bump(&self.stats.lookahead_misses);
        let la = lookahead::shared(&RrGraph::build(device, arch));
        if let Some(d) = &self.disk {
            d.store_lookahead(key, &la);
        }
        Arc::clone(self.lookaheads.lock().unwrap().entry(key).or_insert(la))
    }

    /// Recorded achieved CPD (ps) for a chained seed, if any run under
    /// this cache has produced it.
    pub fn cpd_prior(&self, key: u64) -> Option<f64> {
        self.cpd_priors.lock().unwrap().get(&key).copied()
    }

    /// Record a chained seed's achieved CPD (ps).
    pub fn record_cpd_prior(&self, key: u64, cpd_ps: f64) {
        self.cpd_priors.lock().unwrap().insert(key, cpd_ps);
    }

    /// Number of recorded cross-seed CPD priors (observability).
    pub fn cpd_priors_recorded(&self) -> usize {
        self.cpd_priors.lock().unwrap().len()
    }

    /// Drain the cache-integrity violations the disk layer recorded
    /// (corrupt files it quarantined before rebuilding).  Empty for
    /// memory-only caches.
    pub fn take_cache_violations(&self) -> Vec<Violation> {
        match &self.disk {
            Some(d) => d.take_violations(),
            None => Vec::new(),
        }
    }
}

/// Process-wide failed-seed count across every [`Engine::run`] — the
/// CLI's exit-code source (it cannot thread a return value through the
/// report harness's deeply shared call paths).
static PROCESS_FAILURES: AtomicUsize = AtomicUsize::new(0);

/// Total failed seeds recorded by every engine run in this process.
pub fn process_failures() -> usize {
    PROCESS_FAILURES.load(Ordering::Relaxed)
}

/// Run one engine job under panic isolation: a panic becomes an `Err`
/// carrying the payload text instead of poisoning the scoped work queue
/// (a panicking worker would otherwise abort the whole plan).
fn catch_job<T>(f: impl FnOnce() -> T) -> Result<T, String> {
    catch_unwind(AssertUnwindSafe(f)).map_err(|p| super::panic_message(p.as_ref()))
}

/// Fixed-order end-of-run failure report: per-cell structured errors in
/// (variant, bench, seed) order, escalation notes, and the disk cache's
/// quarantine log.  Built after the grid reduction, so its text is
/// bit-identical for any `--jobs`/`--route-jobs`.
#[derive(Debug, Default)]
pub struct FailureSummary {
    pub failed_seeds: usize,
    pub escalations: usize,
    pub quarantined: usize,
    pub lines: Vec<String>,
}

impl FailureSummary {
    pub fn collect(grid: &[Vec<FlowResult>], cache_violations: &[Violation]) -> FailureSummary {
        let mut s = FailureSummary::default();
        for row in grid {
            for r in row {
                s.failed_seeds += r.failed_seeds;
                s.escalations += r.escalations;
                // Per-cell lines come from the result itself
                // ([`FlowResult::failure_lines`]) so the daemon's per-job
                // failure JSON and this end-of-run summary cannot drift.
                s.lines.extend(r.failure_lines());
            }
        }
        s.quarantined = cache_violations.len();
        for v in cache_violations {
            s.lines.push(format!("[cache] {v}"));
        }
        s
    }

    /// Nothing to report: no failures, no escalations, no quarantines.
    pub fn is_clean(&self) -> bool {
        self.failed_seeds == 0 && self.escalations == 0 && self.quarantined == 0
    }
}

impl std::fmt::Display for FailureSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "flow failure summary: {} failed seed(s), {} escalation(s), {} quarantined cache file(s)",
            self.failed_seeds, self.escalations, self.quarantined
        )?;
        for l in &self.lines {
            writeln!(f, "  {l}")?;
        }
        Ok(())
    }
}

/// The experiment grid: every benchmark on every variant, each averaged
/// over the flow's seeds.
#[derive(Clone)]
pub struct ExperimentPlan {
    pub benches: Vec<Benchmark>,
    pub variants: Vec<ArchVariant>,
    pub flow: FlowOpts,
}

/// Parallel, cached plan executor.
pub struct Engine {
    /// Worker threads for each phase's job queue (1 = serial).
    pub jobs: usize,
    pub cache: Arc<ArtifactCache>,
}

impl Engine {
    /// Engine with a fresh (cold) cache.
    pub fn new(jobs: usize) -> Engine {
        Engine { jobs, cache: Arc::new(ArtifactCache::new()) }
    }

    /// Engine sharing an existing cache (e.g. [`ArtifactCache::global`]).
    pub fn with_cache(jobs: usize, cache: Arc<ArtifactCache>) -> Engine {
        Engine { jobs, cache }
    }

    /// Run the full grid.  `result[v][b]` is benchmark `b` on variant `v`,
    /// bit-identical to `flow::run_benchmark` for the same cell.
    pub fn run(&self, plan: &ExperimentPlan) -> Vec<Vec<FlowResult>> {
        let benches = &plan.benches;
        let variants = &plan.variants;
        let opts = &plan.flow;
        let nb = benches.len();
        let nv = variants.len();
        let ns = opts.seeds.len();
        let cache = &self.cache;

        // Phase 1: map every distinct circuit (variant-independent).
        // When the grid has fewer circuits than workers, the leftover
        // parallelism moves *inside* each mapping job (levelized cut
        // enumeration waves); output is bit-identical either way, so the
        // split is a pure scheduling decision.  Each job runs isolated:
        // a panic fails every grid cell of that circuit, not the plan.
        let map_inner = (self.jobs / nb.max(1)).max(1);
        let mapped: Vec<Result<Arc<MappedCircuit>, FlowError>> =
            parallel_indexed(nb, self.jobs, |bi| {
                catch_job(|| {
                    opts.faults.fire_panic("map", &benches[bi].name, None);
                    let m = cache.mapped_with(&benches[bi], map_inner);
                    // Semantic gate on the mapper's logic-neutrality
                    // contract; strict mode panics here and the job
                    // isolation converts it into this cell's FlowError.
                    if opts.check != CheckMode::Off {
                        let circ = benches[bi].generate();
                        let eq =
                            check::equiv_mapped(&circ, &m.nl, &check::EquivOpts::default());
                        check::enforce(opts.check, "equiv-map", &eq.violations);
                    }
                    m
                })
                .map_err(|cause| {
                    FlowError::stage_failure("map", None, cause, RecoveryAction::SkipCell)
                })
            });

        // Phase 2: pack every (circuit, variant) cell (same inner/outer
        // parallelism split as phase 1); an upstream map failure
        // propagates without running the job.
        let archs: Vec<Arch> = variants
            .iter()
            .map(|&v| arch_for_run(&Arch::coffe(v), opts))
            .collect();
        let pack_inner = (self.jobs / (nb * nv).max(1)).max(1);
        let packs: Vec<Result<Arc<Packing>, FlowError>> =
            parallel_indexed(nb * nv, self.jobs, |i| {
                let (vi, bi) = (i / nb, i % nb);
                let m = mapped[bi].as_ref().map_err(|e| e.clone())?;
                catch_job(|| {
                    opts.faults.fire_panic("pack", &benches[bi].name, None);
                    let p = cache.packed_with(
                        m,
                        &archs[vi],
                        &PackOpts { unrelated: opts.unrelated },
                        pack_inner,
                    );
                    // Packing must be logic-neutral: re-check the packed
                    // view (operand paths applied) against the source AIG.
                    if opts.check != CheckMode::Off {
                        let circ = benches[bi].generate();
                        let eq = check::equiv_packed(
                            &circ,
                            &m.nl,
                            &p,
                            &check::EquivOpts::default(),
                        );
                        check::enforce(opts.check, "equiv-pack", &eq.violations);
                    }
                    p
                })
                .map_err(|cause| {
                    FlowError::stage_failure("pack", None, cause, RecoveryAction::SkipCell)
                })
            });

        // Phase 3a: dense index arenas per (circuit, variant) cell —
        // cached like packings, shared read-only by every seed job.
        let pack_opts = PackOpts { unrelated: opts.unrelated };
        let arenas: Vec<Result<Arc<IndexArenas>, FlowError>> =
            parallel_indexed(nb * nv, self.jobs, |i| {
                let (vi, bi) = (i / nb, i % nb);
                let m = mapped[bi].as_ref().map_err(|e| e.clone())?;
                let p = packs[i].as_ref().map_err(|e| e.clone())?;
                catch_job(|| cache.indexed(m, p, &archs[vi], &pack_opts)).map_err(|cause| {
                    FlowError::stage_failure("index", None, cause, RecoveryAction::SkipCell)
                })
            });

        // Upstream failure of a grid cell, attributed to the earliest
        // failing stage (the later ones only propagated it).
        let upstream_err = |bi: usize, ci: usize| -> FlowError {
            mapped[bi]
                .as_ref()
                .err()
                .or(packs[ci].as_ref().err())
                .or(arenas[ci].as_ref().err())
                .cloned()
                .unwrap_or_else(|| {
                    FlowError::stage_failure(
                        "index",
                        None,
                        "upstream artifact unavailable".to_string(),
                        RecoveryAction::SkipCell,
                    )
                })
        };

        // Phase 3b: place/route.  Timing-oblivious plans fan out one job
        // per (circuit, variant, seed).  With the closed timing loop on,
        // each cell's seeds are a *chain* — seed i's achieved CPD is seed
        // i+1's criticality prior ([`crate::flow::chain_seeds`], shared
        // with the serial path) — so the job unit becomes the cell (cells
        // still run in parallel) and every achieved CPD is recorded in
        // the artifact cache; fixed seed order keeps results
        // bit-identical to the serial path.
        let seed_runs: Vec<SeedMetrics> = if opts.route && opts.route_timing_weights {
            let cells: Vec<Vec<SeedMetrics>> = parallel_indexed(nb * nv, self.jobs, |i| {
                let (vi, bi) = (i / nb, i % nb);
                let (m, p, ar) = match (&mapped[bi], &packs[i], &arenas[i]) {
                    (Ok(m), Ok(p), Ok(ar)) => (m, p, ar),
                    _ => {
                        let e = upstream_err(bi, i);
                        return opts
                            .seeds
                            .iter()
                            .map(|&s| SeedMetrics::failed(s, None, e.clone()))
                            .collect();
                    }
                };
                super::chain_seeds(
                    &m.nl,
                    p,
                    &archs[vi],
                    opts,
                    &benches[bi].name,
                    &ar.idx,
                    &ar.pidx,
                    Some(cache),
                    |si, cpd_ps| {
                        let key = ArtifactCache::cpd_prior_key(
                            m.fingerprint,
                            &archs[vi],
                            opts,
                            &opts.seeds[..=si],
                        );
                        cache.record_cpd_prior(key, cpd_ps);
                    },
                    |_, _| {},
                )
            });
            // Cells are produced in (variant, bench) order; flattening
            // yields exactly the (variant, bench, seed) layout phase 4
            // reduces over.
            cells.into_iter().flatten().collect()
        } else {
            parallel_indexed(nb * nv * ns, self.jobs, |i| {
                let si = i % ns;
                let bi = (i / ns) % nb;
                let vi = i / (ns * nb);
                let ci = vi * nb + bi;
                match (&mapped[bi], &packs[ci], &arenas[ci]) {
                    (Ok(m), Ok(p), Ok(ar)) => place_route_seed(
                        &m.nl,
                        p,
                        &archs[vi],
                        opts,
                        opts.seeds[si],
                        &SeedCtx {
                            idx: &ar.idx,
                            pidx: &ar.pidx,
                            cpd_prior_ps: None,
                            la_cache: Some(cache),
                            label: &benches[bi].name,
                        },
                    ),
                    _ => SeedMetrics::failed(opts.seeds[si], None, upstream_err(bi, ci)),
                }
            })
        };

        // Phase 4: reduce per cell in fixed (variant, bench, seed) order.
        let chained = opts.route && opts.route_timing_weights;
        let mut out: Vec<Vec<FlowResult>> = Vec::with_capacity(nv);
        for vi in 0..nv {
            let mut row = Vec::with_capacity(nb);
            for bi in 0..nb {
                let ci = vi * nb + bi;
                let base = ci * ns;
                let cell_seeds = &seed_runs[base..base + ns];
                let r = match &packs[ci] {
                    Ok(p) => {
                        let dedup = mapped[bi].as_ref().map(|m| m.dedup_hits).unwrap_or(0);
                        assemble_result(&benches[bi].name, &archs[vi], p, cell_seeds, dedup)
                    }
                    // No packing — the whole cell failed upstream; carry
                    // the failure as data so the grid keeps its shape.
                    Err(_) => FlowResult::failed(
                        &benches[bi].name,
                        variants[vi],
                        upstream_err(bi, ci),
                        ns,
                    ),
                };
                if opts.check != CheckMode::Off {
                    check::enforce(
                        opts.check,
                        "recovery",
                        &check::audit_recovery(&r, cell_seeds, chained),
                    );
                }
                row.push(r);
            }
            out.push(row);
        }

        // End-of-run failure summary, in the same fixed (variant, bench)
        // order as the reduction — deterministic text for any worker
        // count.  Failed seeds feed the process-wide exit-code counter;
        // escalations and quarantines are reported but not fatal.
        let cache_violations = cache.take_cache_violations();
        let summary = FailureSummary::collect(&out, &cache_violations);
        if !summary.is_clean() {
            eprintln!("{summary}");
        }
        PROCESS_FAILURES.fetch_add(summary.failed_seeds, Ordering::Relaxed);
        out
    }
}

/// Cached equivalent of [`crate::flow::run_benchmark`]: identical results,
/// but the mapped netlist, packing, and index arenas come from (and feed)
/// `cache` — including the chained cross-seed CPD priors of the closed
/// timing loop.
pub fn run_benchmark_cached(
    cache: &ArtifactCache,
    b: &Benchmark,
    variant: ArchVariant,
    opts: &FlowOpts,
) -> FlowResult {
    run_benchmark_cached_with(cache, b, variant, opts, |_, _| {})
}

/// [`run_benchmark_cached`] with a per-seed progress observer: `on_seed`
/// fires in fixed seed order the moment each seed finishes (the tap `dd
/// serve` streams incremental job events from).  Observation cannot alter
/// the result — this is `chain_seeds`' observer threaded through the
/// cached runner, so daemon results stay byte-identical to the batch CLI.
pub fn run_benchmark_cached_with(
    cache: &ArtifactCache,
    b: &Benchmark,
    variant: ArchVariant,
    opts: &FlowOpts,
    on_seed: impl FnMut(usize, &SeedMetrics),
) -> FlowResult {
    let mapped = cache.mapped(b);
    let arch = arch_for_run(&Arch::coffe(variant), opts);
    let pack_opts = PackOpts { unrelated: opts.unrelated };
    let packing = cache.packed(&mapped, &arch, &pack_opts);
    let arenas = cache.indexed(&mapped, &packing, &arch, &pack_opts);
    let seeds = super::chain_seeds(
        &mapped.nl,
        &packing,
        &arch,
        opts,
        &b.name,
        &arenas.idx,
        &arenas.pidx,
        Some(cache),
        |si, cpd_ps| {
            let key = ArtifactCache::cpd_prior_key(
                mapped.fingerprint,
                &arch,
                opts,
                &opts.seeds[..=si],
            );
            cache.record_cpd_prior(key, cpd_ps);
        },
        on_seed,
    );
    assemble_result(&b.name, &arch, &packing, &seeds, mapped.dedup_hits)
}

/// One (benchmark, variant) flow cell — the unit of work [`PlanQueue`]
/// schedules and `dd serve` accepts over the wire.
#[derive(Clone)]
pub struct CellJob {
    pub bench: Benchmark,
    pub variant: ArchVariant,
    pub flow: FlowOpts,
}

impl CellJob {
    /// Content-addressed submission identity: two submissions with equal
    /// keys are guaranteed to produce byte-identical results, so the
    /// queue runs one and serves both.  Hashes the benchmark's generator
    /// identity, the variant, and every [`FlowOpts`] field via exhaustive
    /// destructuring (a new knob fails compilation here instead of
    /// silently aliasing submissions) — except `route_jobs`, which is
    /// excluded *by the determinism contract*: results are bit-identical
    /// for any worker count, so submissions differing only in worker
    /// count must dedup onto one execution.
    pub fn submission_key(&self) -> u64 {
        let mut h = DefaultHasher::new();
        ArtifactCache::bench_key(&self.bench).hash(&mut h);
        self.variant.hash(&mut h);
        let FlowOpts {
            seeds,
            place_effort,
            unrelated,
            route,
            route_jobs: _,
            route_timing_weights,
            sta_every,
            crit_alpha,
            place_crit_alpha,
            move_mix,
            use_kernel,
            device,
            channel_width,
            check,
            lookahead,
            escalate,
            route_pops_budget,
            faults,
        } = &self.flow;
        seeds.hash(&mut h);
        place_effort.to_bits().hash(&mut h);
        (match unrelated {
            Unrelated::Off => 0u8,
            Unrelated::Auto => 1u8,
            Unrelated::On => 2u8,
        })
        .hash(&mut h);
        route.hash(&mut h);
        route_timing_weights.hash(&mut h);
        sta_every.hash(&mut h);
        crit_alpha.to_bits().hash(&mut h);
        place_crit_alpha.to_bits().hash(&mut h);
        move_mix.to_bits().hash(&mut h);
        use_kernel.hash(&mut h);
        if let Some(d) = device {
            d.lb_cols.hash(&mut h);
            d.lb_rows.hash(&mut h);
            d.io_per_tile.hash(&mut h);
        }
        channel_width.hash(&mut h);
        // `check` shapes results too: a strict run fails where a warning
        // run proceeds, so the modes must not alias.
        (match check {
            CheckMode::Off => 0u8,
            CheckMode::Warn => 1u8,
            CheckMode::Strict => 2u8,
        })
        .hash(&mut h);
        lookahead.hash(&mut h);
        escalate.hash(&mut h);
        route_pops_budget.hash(&mut h);
        faults.hash(&mut h);
        h.finish()
    }
}

impl ExperimentPlan {
    /// Decompose the grid into its (variant, bench) cells, in the fixed
    /// order [`Engine::run`]'s reduction walks — the unit [`PlanQueue`]
    /// schedules, which is what makes a running plan *appendable*:
    /// appending benches or variants is just submitting more cells.
    pub fn cells(&self) -> Vec<CellJob> {
        let mut out = Vec::with_capacity(self.variants.len() * self.benches.len());
        for &variant in &self.variants {
            for bench in &self.benches {
                out.push(CellJob { bench: bench.clone(), variant, flow: self.flow.clone() });
            }
        }
        out
    }
}

/// Lifecycle of one queued job.  Transitions are strictly
/// `Scheduled → Running → Done | Failed`; `Done`/`Failed` are terminal
/// (`check::audit_serve` re-verifies this from the event log).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Scheduled,
    Running,
    Done,
    Failed,
}

impl JobState {
    /// Wire name (the daemon's JSON `state` field).
    pub fn name(self) -> &'static str {
        match self {
            JobState::Scheduled => "scheduled",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed)
    }
}

/// One entry of a job's ordered progress log: a state transition, or a
/// finished seed's metrics (`cpd_trace`, PathFinder iterations,
/// `astar_pops`) — what the daemon streams as incremental chunks.
#[derive(Clone, Debug)]
pub enum JobEvent {
    State(JobState),
    Seed { index: usize, metrics: SeedMetrics },
}

/// Point-in-time copy of one queue job (id, identity, state, event log,
/// result when terminal) — the read model for the daemon's endpoints and
/// for `check::audit_serve`.
#[derive(Clone)]
pub struct JobSnapshot {
    pub id: usize,
    pub key: u64,
    pub bench: String,
    pub variant: ArchVariant,
    pub n_seeds: usize,
    pub state: JobState,
    pub events: Vec<JobEvent>,
    pub result: Option<FlowResult>,
}

struct QueueJob {
    job: CellJob,
    key: u64,
    state: JobState,
    events: Vec<JobEvent>,
    result: Option<FlowResult>,
}

#[derive(Default)]
struct QueueState {
    /// Job ids awaiting a worker, in submission order.
    pending: VecDeque<usize>,
    /// Every job ever submitted, indexed by id (ids are dense).
    jobs: Vec<QueueJob>,
    /// Submission dedup index: key → job id.  Insert/lookup only — never
    /// iterated (hash order must stay unobservable).
    by_key: HashMap<u64, usize>,
    /// Submissions answered by an existing job instead of a new one.
    dedup_hits: usize,
    shutdown: bool,
}

struct QueueShared {
    cache: Arc<ArtifactCache>,
    state: Mutex<QueueState>,
    cond: Condvar,
    /// Jobs a worker actually started executing (the CI smoke's
    /// "identical resubmission executed nothing" counter).
    executed: AtomicUsize,
}

/// Resident, appendable work queue over the engine's [`ArtifactCache`]:
/// the daemon-facing counterpart of [`Engine::run`] (see the module
/// docs).  Submissions dedup by [`CellJob::submission_key`]; each job is
/// executed once, under the same panic isolation as engine jobs, and its
/// state/events/result stay queryable for the queue's lifetime.
pub struct PlanQueue {
    shared: Arc<QueueShared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl PlanQueue {
    /// Start `workers` resident worker threads over `cache`.
    pub fn start(workers: usize, cache: Arc<ArtifactCache>) -> PlanQueue {
        let shared = Arc::new(QueueShared {
            cache,
            state: Mutex::new(QueueState::default()),
            cond: Condvar::new(),
            executed: AtomicUsize::new(0),
        });
        let n = workers.max(1);
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let sh = Arc::clone(&shared);
            handles.push(std::thread::spawn(move || worker_loop(&sh)));
        }
        PlanQueue { shared, workers: Mutex::new(handles) }
    }

    /// Submit one cell.  Returns `(job id, fresh)`: `fresh = false` means
    /// an identical submission already exists (scheduled, running, or
    /// finished) and this one was deduped onto it — the queue will never
    /// execute the cell a second time.
    pub fn submit(&self, job: CellJob) -> (usize, bool) {
        let key = job.submission_key();
        let mut st = self.shared.state.lock().unwrap();
        if let Some(&id) = st.by_key.get(&key) {
            st.dedup_hits += 1;
            return (id, false);
        }
        let id = st.jobs.len();
        st.by_key.insert(key, id);
        st.jobs.push(QueueJob {
            job,
            key,
            state: JobState::Scheduled,
            events: vec![JobEvent::State(JobState::Scheduled)],
            result: None,
        });
        st.pending.push_back(id);
        drop(st);
        self.shared.cond.notify_all();
        (id, true)
    }

    /// Append every cell of `plan` to the (possibly running) queue, in
    /// the plan's fixed (variant, bench) order.  Returns one
    /// `(job id, fresh)` pair per cell, in that order.
    pub fn append_plan(&self, plan: &ExperimentPlan) -> Vec<(usize, bool)> {
        plan.cells().into_iter().map(|c| self.submit(c)).collect()
    }

    /// Snapshot one job, or `None` for an unknown id.
    pub fn snapshot(&self, id: usize) -> Option<JobSnapshot> {
        let st = self.shared.state.lock().unwrap();
        st.jobs.get(id).map(|j| snap(id, j))
    }

    /// Snapshot every job, in submission (id) order.
    pub fn snapshots(&self) -> Vec<JobSnapshot> {
        let st = self.shared.state.lock().unwrap();
        st.jobs.iter().enumerate().map(|(id, j)| snap(id, j)).collect()
    }

    /// Block until job `id` has events beyond the `seen` already
    /// consumed, or is terminal.  Returns the new events (possibly empty
    /// when terminal) and the current state — the daemon's incremental
    /// event stream reads off this.  `None` for an unknown id.
    pub fn wait_progress(&self, id: usize, seen: usize) -> Option<(JobState, Vec<JobEvent>)> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            let j = st.jobs.get(id)?;
            if j.events.len() > seen || j.state.is_terminal() {
                let from = seen.min(j.events.len());
                return Some((j.state, j.events[from..].to_vec()));
            }
            st = self.shared.cond.wait(st).unwrap();
        }
    }

    /// Block until job `id` is terminal; returns its result (`None` only
    /// for an unknown id — terminal jobs always carry a result).
    pub fn wait_terminal(&self, id: usize) -> Option<FlowResult> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            let j = st.jobs.get(id)?;
            if j.state.is_terminal() {
                return j.result.clone();
            }
            st = self.shared.cond.wait(st).unwrap();
        }
    }

    /// Jobs a worker actually started executing (dedup'd submissions
    /// never count).
    pub fn executed(&self) -> usize {
        self.shared.executed.load(Ordering::Relaxed)
    }

    /// Submissions answered by an existing job.
    pub fn dedup_hits(&self) -> usize {
        self.shared.state.lock().unwrap().dedup_hits
    }

    /// Total jobs ever submitted (dedup'd submissions excluded).
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The artifact cache the workers run over.
    pub fn cache(&self) -> &Arc<ArtifactCache> {
        &self.shared.cache
    }

    /// Drain the queue and stop: workers finish every pending job (jobs
    /// are deterministic and bounded — there are no wall-clock timeouts
    /// to hang on), then exit; blocks until all have joined.  Jobs
    /// submitted after this call may never run.
    pub fn shutdown_and_join(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cond.notify_all();
        let mut ws = self.workers.lock().unwrap();
        for w in ws.drain(..) {
            let _ = w.join();
        }
    }
}

fn snap(id: usize, j: &QueueJob) -> JobSnapshot {
    JobSnapshot {
        id,
        key: j.key,
        bench: j.job.bench.name.clone(),
        variant: j.job.variant,
        n_seeds: j.job.flow.seeds.len(),
        state: j.state,
        events: j.events.clone(),
        result: j.result.clone(),
    }
}

fn worker_loop(shared: &Arc<QueueShared>) {
    loop {
        // Claim the oldest pending job; park until one exists.  Workers
        // drain the queue before honoring shutdown, so a clean daemon
        // stop never abandons an accepted job.
        let (id, job) = {
            let mut st = shared.state.lock().unwrap();
            let id = loop {
                if let Some(id) = st.pending.pop_front() {
                    break id;
                }
                if st.shutdown {
                    return;
                }
                st = shared.cond.wait(st).unwrap();
            };
            st.jobs[id].state = JobState::Running;
            st.jobs[id].events.push(JobEvent::State(JobState::Running));
            (id, st.jobs[id].job.clone())
        };
        shared.executed.fetch_add(1, Ordering::Relaxed);
        shared.cond.notify_all();

        // Same panic isolation as engine jobs: a panicking stage becomes
        // a Failed job carrying the structured error, not a dead worker.
        // The per-seed observer appends Seed events under the queue lock
        // and wakes streamers — observation only, the chain itself runs
        // in `chain_seeds` untouched.
        let outcome = catch_job(|| {
            run_benchmark_cached_with(
                &shared.cache,
                &job.bench,
                job.variant,
                &job.flow,
                |si, m| {
                    let mut st = shared.state.lock().unwrap();
                    st.jobs[id].events.push(JobEvent::Seed { index: si, metrics: m.clone() });
                    drop(st);
                    shared.cond.notify_all();
                },
            )
        });
        let (state, result) = match outcome {
            Ok(r) => {
                let s = if r.failed_seeds == 0 { JobState::Done } else { JobState::Failed };
                (s, r)
            }
            Err(cause) => (
                JobState::Failed,
                FlowResult::failed(
                    &job.bench.name,
                    job.variant,
                    FlowError::job_panic(None, cause),
                    job.flow.seeds.len(),
                ),
            ),
        };
        {
            let mut st = shared.state.lock().unwrap();
            st.jobs[id].state = state;
            st.jobs[id].events.push(JobEvent::State(state));
            st.jobs[id].result = Some(result);
        }
        shared.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suites::{vtr_suite, BenchParams};

    fn tiny_plan() -> ExperimentPlan {
        let params = BenchParams::default();
        ExperimentPlan {
            benches: vtr_suite(&params)[..2].to_vec(),
            variants: vec![ArchVariant::Baseline, ArchVariant::Dd5],
            flow: FlowOpts {
                seeds: vec![1, 2],
                place_effort: 0.05,
                route: false,
                ..Default::default()
            },
        }
    }

    #[test]
    fn grid_shape_and_names() {
        let plan = tiny_plan();
        let grid = Engine::new(2).run(&plan);
        assert_eq!(grid.len(), 2);
        for row in &grid {
            assert_eq!(row.len(), 2);
            for (r, b) in row.iter().zip(&plan.benches) {
                assert_eq!(r.name, b.name);
                assert!(r.alms > 0 && r.cpd_ns > 0.0);
            }
        }
        assert_eq!(grid[0][0].variant, ArchVariant::Baseline);
        assert_eq!(grid[1][0].variant, ArchVariant::Dd5);
    }

    #[test]
    fn cache_shares_mapped_across_variants() {
        let plan = tiny_plan();
        let engine = Engine::new(2);
        let _ = engine.run(&plan);
        let s = &engine.cache.stats;
        // 2 circuits mapped once each; 2x2 packings, no repeats.
        assert_eq!(s.map_misses.load(Ordering::Relaxed), 2);
        assert_eq!(s.pack_misses.load(Ordering::Relaxed), 4);
        // Re-running the same plan is served entirely from the cache.
        let _ = engine.run(&plan);
        assert_eq!(s.map_misses.load(Ordering::Relaxed), 2);
        assert_eq!(s.pack_misses.load(Ordering::Relaxed), 4);
        assert!(s.map_hits.load(Ordering::Relaxed) >= 2);
        assert!(s.pack_hits.load(Ordering::Relaxed) >= 4);
    }

    /// A second cache instance sharing the same disk root revives both
    /// artifacts without recomputing, and they match the cold versions.
    #[test]
    fn disk_cache_revives_artifacts_across_instances() {
        let root = std::env::temp_dir()
            .join(format!("dd-cache-engine-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let params = BenchParams::default();
        let b = &vtr_suite(&params)[0];
        let arch = Arch::coffe(ArchVariant::Dd5);
        let opts = crate::pack::PackOpts::default();

        let cold = ArtifactCache::with_disk(DiskCache::new(&root));
        let m0 = cold.mapped(b);
        let p0 = cold.packed(&m0, &arch, &opts);
        assert_eq!(cold.stats.map_misses.load(Ordering::Relaxed), 1);
        assert_eq!(cold.stats.map_disk_hits.load(Ordering::Relaxed), 0);

        let warm = ArtifactCache::with_disk(DiskCache::new(&root));
        let m1 = warm.mapped(b);
        let p1 = warm.packed(&m1, &arch, &opts);
        assert_eq!(warm.stats.map_misses.load(Ordering::Relaxed), 0);
        assert_eq!(warm.stats.map_disk_hits.load(Ordering::Relaxed), 1);
        assert_eq!(warm.stats.pack_misses.load(Ordering::Relaxed), 0);
        assert_eq!(warm.stats.pack_disk_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m0.fingerprint, m1.fingerprint);
        assert_eq!(m0.dedup_hits, m1.dedup_hits);
        assert_eq!(p0.stats.alms, p1.stats.alms);
        assert_eq!(p0.chain_macros, p1.chain_macros);
        let _ = std::fs::remove_dir_all(&root);
    }

    /// The lookahead layer: in-memory memo, then disk revival across
    /// cache instances, with the stats counters tracking each tier.
    #[test]
    fn lookahead_cache_memoizes_and_revives_from_disk() {
        let root = std::env::temp_dir()
            .join(format!("dd-cache-lookahead-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let device = Device::new(6, 6);
        let arch = Arch::coffe(ArchVariant::Baseline);

        let cold = ArtifactCache::with_disk(DiskCache::new(&root));
        let a = cold.lookahead(&device, &arch);
        assert_eq!(cold.stats.lookahead_misses.load(Ordering::Relaxed), 1);
        let b = cold.lookahead(&device, &arch);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cold.stats.lookahead_hits.load(Ordering::Relaxed), 1);

        let warm = ArtifactCache::with_disk(DiskCache::new(&root));
        let c = warm.lookahead(&device, &arch);
        assert_eq!(warm.stats.lookahead_misses.load(Ordering::Relaxed), 0);
        assert_eq!(warm.stats.lookahead_disk_hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.dist(), a.dist());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn fingerprint_distinguishes_netlists() {
        let params = BenchParams::default();
        let suite = vtr_suite(&params);
        let cache = ArtifactCache::new();
        let a = cache.mapped(&suite[0]);
        let b = cache.mapped(&suite[1]);
        assert_ne!(a.fingerprint, b.fingerprint);
        // Same benchmark -> same artifact instance.
        let a2 = cache.mapped(&suite[0]);
        assert!(Arc::ptr_eq(&a, &a2));
    }
}
