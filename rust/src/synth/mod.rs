//! Arithmetic-aware synthesis front-end (the paper's Parmys enhancements).
//!
//! A [`circuit::Circuit`] couples an AIG (soft logic) with hard carry-chain
//! adder macros.  On top of it, [`multiplier`] implements the paper's §IV
//! algorithms: unrolled-multiplication deduplication with selector-bit row
//! elision, the strength-heuristic binary adder tree (Algorithm 1), and the
//! Proposed-Wallace / Dadda compressor trees, plus the naive cascade and a
//! VTR-baseline mode (no dedup) for the Fig. 5 comparison.

pub mod circuit;
pub mod multiplier;

pub use circuit::{AdderChainMacro, Circuit};
pub use multiplier::{reduce_rows, soft_mul, unrolled_mul, AdderAlgo, Rows};
