//! Tseitin CNF encoding of one miter cone.
//!
//! Given the miter AIG and one output's miter literal (spec XOR impl),
//! this walks the cone reachable from that literal, gives every reachable
//! node a CNF variable in ascending node-id order (so the encoding — and
//! therefore the whole SAT search — is a pure function of the cone), and
//! emits the standard three clauses per AND node
//! `c = a ∧ b  ⇒  (¬c ∨ a)(¬c ∨ b)(¬a ∨ ¬b ∨ c)`
//! plus a unit clause asserting the miter literal true.  `Const0` gets a
//! variable pinned false by a unit clause.  A satisfying model is then a
//! counterexample input assignment; UNSAT proves the cone equivalent.

use super::sat::{SLit, Solver, Var};
use crate::techmap::aig::{Aig, LeafKind, Lit, Node};

/// One encoded cone: a ready-to-solve [`Solver`] plus the map from miter
/// primary-input index to CNF variable (for decoding SAT models back into
/// input assignments).  Inputs outside the cone are unconstrained and
/// simply absent from `inputs`.
pub struct ConeCnf {
    pub solver: Solver,
    /// `(miter input index, CNF variable)` pairs, input index ascending.
    pub inputs: Vec<(u32, Var)>,
}

/// Encode the cone of `root` (a miter literal) into CNF.  Returns `None`
/// when the cone contains a leaf kind other than `Pi` — the miter builder
/// only emits `Pi` leaves, so anything else is a construction bug that
/// must surface as "undecided", never as a panic or a wrong verdict.
pub fn encode_cone(aig: &Aig, root: Lit) -> Option<ConeCnf> {
    // --- Reachability (iterative DFS). -----------------------------------
    let n = aig.len();
    let mut reach = vec![false; n];
    let mut stack = vec![root.node()];
    while let Some(id) = stack.pop() {
        let idu = id as usize;
        if idu >= n || reach[idu] {
            continue;
        }
        reach[idu] = true;
        if let Node::And(a, b) = *aig.node(id) {
            stack.push(a.node());
            stack.push(b.node());
        }
    }

    // --- Variable numbering, ascending node id (deterministic). ---------
    let mut var_of = vec![u32::MAX; n];
    let mut n_vars = 0u32;
    for id in 0..n {
        if reach[id] {
            var_of[id] = n_vars;
            n_vars += 1;
        }
    }

    let lit_of = |l: Lit| -> SLit { SLit::new(var_of[l.node() as usize], l.is_compl()) };

    // --- Clauses, ascending node id. -------------------------------------
    let mut solver = Solver::new(n_vars as usize);
    let mut inputs: Vec<(u32, Var)> = Vec::new();
    for id in 0..n {
        if !reach[id] {
            continue;
        }
        let v = var_of[id];
        match *aig.node(id as u32) {
            Node::Const0 => solver.add_clause(&[SLit::new(v, true)]),
            Node::Leaf(LeafKind::Pi(i)) => inputs.push((i, v)),
            Node::Leaf(_) => return None,
            Node::And(a, b) => {
                let c = SLit::new(v, false);
                let la = lit_of(a);
                let lb = lit_of(b);
                solver.add_clause(&[c.negate(), la]);
                solver.add_clause(&[c.negate(), lb]);
                solver.add_clause(&[la.negate(), lb.negate(), c]);
            }
        }
    }
    solver.add_clause(&[lit_of(root)]);
    Some(ConeCnf { solver, inputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::equiv::sat::SatResult;

    /// Encoding a tautologically-false miter (x XOR x) must be UNSAT.
    #[test]
    fn self_miter_is_unsat() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let f1 = g.and(a, b);
        let f2 = g.and(b, a); // strash-folded to f1
        let m = g.xor(f1, f2);
        assert_eq!(m, Lit::FALSE); // folded before CNF is even needed
        // Force a structural (non-folded) pair: and(a,b) vs !(!a | !b).
        let na_or_nb = g.or(a.compl(), b.compl());
        let m2 = g.xor(f1, na_or_nb.compl());
        if m2 == Lit::FALSE {
            return; // folded — equivalence is already proven
        }
        let cnf = encode_cone(&g, m2).expect("pi-only cone");
        let mut s = cnf.solver;
        assert_eq!(s.solve(10_000), SatResult::Unsat);
    }

    /// A real inequivalence (AND vs OR) must be SAT and the model must
    /// witness the disagreement.
    #[test]
    fn and_vs_or_miter_is_sat_with_witness() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let f1 = g.and(a, b);
        let f2 = g.or(a, b);
        let m = g.xor(f1, f2);
        let cnf = encode_cone(&g, m).expect("pi-only cone");
        let mut s = cnf.solver;
        let SatResult::Sat(model) = s.solve(10_000) else {
            panic!("expected sat");
        };
        // Decode the input assignment and replay it on the AIG.
        let mut pis = [false; 2];
        for &(i, v) in &cnf.inputs {
            pis[i as usize] = model[v as usize];
        }
        let eval = |l: Lit| {
            g.eval(l, |k| match k {
                LeafKind::Pi(i) => pis[i as usize],
                _ => unreachable!(),
            })
        };
        assert_ne!(eval(f1), eval(f2), "model must witness a disagreement");
    }

    /// Constant nodes in the cone are pinned by unit clauses.
    #[test]
    fn const_in_cone() {
        let mut g = Aig::new();
        let a = g.pi();
        // Miter: a XOR (a OR false) — folds or not, either way not SAT.
        let f2 = g.or(a, Lit::FALSE);
        let m = g.xor(a, f2);
        if m == Lit::FALSE {
            return;
        }
        let cnf = encode_cone(&g, m).expect("pi-only cone");
        let mut s = cnf.solver;
        assert_eq!(s.solve(10_000), SatResult::Unsat);
    }
}
