//! Benchmark-suite generators standing in for Kratos, Koios, and the VTR
//! standard benchmarks (see DESIGN.md "Substitutions").
//!
//! Each generator produces a [`crate::synth::Circuit`] with the structural
//! profile the paper reports for its suite (Table III): Kratos is
//! adder-dominated unrolled-DNN arithmetic (~61% adder share), Koios mixes
//! ML datapaths with control (~22%), VTR is general logic (~19%).
//! Instances are scaled down from the paper's (up to 360k-ALM) circuits to
//! container-friendly sizes; all results are reported *normalized*, which
//! is scale-stable (DESIGN.md "Scaling note").

pub mod koios;
pub mod kratos;
pub mod vtr;

use crate::synth::multiplier::AdderAlgo;
use crate::synth::Circuit;

/// A named benchmark: generator + suite tag.
#[derive(Clone)]
pub struct Benchmark {
    pub name: String,
    pub suite: Suite,
    gen: fn(&BenchParams) -> Circuit,
    pub params: BenchParams,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Suite {
    Kratos,
    Koios,
    Vtr,
}

impl Suite {
    pub fn name(self) -> &'static str {
        match self {
            Suite::Kratos => "kratos",
            Suite::Koios => "koios",
            Suite::Vtr => "vtr",
        }
    }
}

/// Generator parameters (the knobs Kratos exposes).
#[derive(Clone, Debug)]
pub struct BenchParams {
    /// Data width in bits (paper evaluates width 6 in Fig. 7).
    pub width: usize,
    /// Weight sparsity in [0, 1] (fraction of zero weights).
    pub sparsity: f64,
    /// Scale factor on the instance size.
    pub scale: usize,
    /// Reduction algorithm for synthesized arithmetic.
    pub algo: AdderAlgo,
    /// RNG seed for weights/structure.
    pub seed: u64,
}

impl Default for BenchParams {
    fn default() -> Self {
        BenchParams {
            width: 6,
            sparsity: 0.5,
            scale: 1,
            algo: AdderAlgo::Wallace,
            seed: 42,
        }
    }
}

impl std::hash::Hash for BenchParams {
    /// Content hash used by the experiment engine's artifact cache.
    ///
    /// Exhaustive destructuring on purpose: adding a generator knob to
    /// this struct without including it in the hash would silently alias
    /// distinct benchmarks in the cache — with it, forgetting is a
    /// compile error here.
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        let BenchParams { width, sparsity, scale, algo, seed } = self;
        width.hash(state);
        sparsity.to_bits().hash(state);
        scale.hash(state);
        algo.hash(state);
        seed.hash(state);
    }
}

impl Benchmark {
    pub fn generate(&self) -> Circuit {
        (self.gen)(&self.params)
    }

    pub fn with_algo(&self, algo: AdderAlgo) -> Benchmark {
        let mut b = self.clone();
        b.params.algo = algo;
        b
    }
}

/// Create a circuit honoring the baseline-VTR dedup switch: the
/// `VtrBaseline` algorithm models stock VTR, which does not share
/// duplicate adder chains.
pub(crate) fn new_circuit(name: &str, p: &BenchParams) -> Circuit {
    let mut c = Circuit::new(name);
    if p.algo == AdderAlgo::VtrBaseline {
        c.disable_dedup();
    }
    c
}

/// The Kratos-like suite (7 circuits, as in the paper).
pub fn kratos_suite(params: &BenchParams) -> Vec<Benchmark> {
    let mk = |name: &str, gen: fn(&BenchParams) -> Circuit| Benchmark {
        name: name.to_string(),
        suite: Suite::Kratos,
        gen,
        params: params.clone(),
    };
    vec![
        mk("conv1d-FU-mini", kratos::conv1d),
        mk("conv2d-FU-mini", kratos::conv2d),
        mk("gemmt-FU-mini", kratos::gemmt),
        mk("gemms-FU-mini", kratos::gemms),
        mk("dwconv-FU-mini", kratos::dwconv),
        mk("mlp-FU-mini", kratos::mlp),
        mk("pool-FU-mini", kratos::pool),
    ]
}

/// The Koios-like suite (8 scaled ML circuits).
pub fn koios_suite(params: &BenchParams) -> Vec<Benchmark> {
    let mk = |name: &str, gen: fn(&BenchParams) -> Circuit| Benchmark {
        name: name.to_string(),
        suite: Suite::Koios,
        gen,
        params: params.clone(),
    };
    vec![
        mk("dla-like", koios::mac_array),
        mk("clstm-like", koios::gate_stack),
        mk("attention-like", koios::attention),
        mk("tpu-like", koios::systolic),
        mk("softmax-like", koios::softmax),
        mk("conv-layer-like", koios::conv_layer),
        mk("reduction-like", koios::reduction),
        mk("norm-like", koios::norm),
    ]
}

/// The VTR-standard-like suite (8 general circuits).
pub fn vtr_suite(params: &BenchParams) -> Vec<Benchmark> {
    let mk = |name: &str, gen: fn(&BenchParams) -> Circuit| Benchmark {
        name: name.to_string(),
        suite: Suite::Vtr,
        gen,
        params: params.clone(),
    };
    vec![
        mk("sha-like", vtr::sha_rounds),
        mk("alu-like", vtr::alu),
        mk("fsm-like", vtr::fsm),
        mk("xbar-like", vtr::crossbar),
        mk("counter-like", vtr::counters),
        mk("cordic-like", vtr::cordic),
        mk("fir-like", vtr::fir),
        mk("parity-like", vtr::parity),
    ]
}

/// Everything, tagged.
pub fn all_suites(params: &BenchParams) -> Vec<Benchmark> {
    let mut v = kratos_suite(params);
    v.extend(koios_suite(params));
    v.extend(vtr_suite(params));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::NetlistStats;
    use crate::techmap::{map_circuit, MapOpts};

    /// Suite adder-share profile must match Table III's ordering:
    /// Kratos >> Koios >~ VTR.
    #[test]
    fn suite_adder_profiles_match_paper() {
        let params = BenchParams { scale: 1, ..Default::default() };
        let share = |suite: Vec<Benchmark>| {
            let mut fracs = Vec::new();
            for b in suite {
                let c = b.generate();
                let nl = map_circuit(&c, &MapOpts::default());
                fracs.push(NetlistStats::of(&nl).adder_fraction);
            }
            crate::util::stats::mean(&fracs)
        };
        let k = share(kratos_suite(&params));
        let o = share(koios_suite(&params));
        let v = share(vtr_suite(&params));
        assert!(k > 0.4, "kratos adder share {k}");
        assert!(k > o && o > 0.08, "koios {o} vs kratos {k}");
        assert!(v < 0.35, "vtr adder share {v}");
    }

    /// Every benchmark generates, maps, and passes netlist checks.
    #[test]
    fn all_benchmarks_generate_and_map() {
        let params = BenchParams { scale: 1, ..Default::default() };
        for b in all_suites(&params) {
            let c = b.generate();
            assert!(!c.pos.is_empty(), "{} has no outputs", b.name);
            let nl = map_circuit(&c, &MapOpts::default());
            let errs = nl.check();
            assert!(errs.is_empty(), "{}: {:?}", b.name, errs);
            assert!(nl.num_luts() + nl.num_adders() > 10, "{} trivial", b.name);
        }
    }

    /// Sparsity knob reduces arithmetic (Kratos' defining feature).
    #[test]
    fn sparsity_reduces_adders() {
        let dense = BenchParams { sparsity: 0.0, ..Default::default() };
        let sparse = BenchParams { sparsity: 0.8, ..Default::default() };
        let count = |p: &BenchParams| {
            let c = kratos::conv1d(p);
            c.num_adder_bits()
        };
        assert!(count(&sparse) < count(&dense));
    }

    /// Width knob scales arithmetic.
    #[test]
    fn width_scales_adders() {
        let w4 = BenchParams { width: 4, ..Default::default() };
        let w8 = BenchParams { width: 8, ..Default::default() };
        assert!(kratos::gemmt(&w8).num_adder_bits() > kratos::gemmt(&w4).num_adder_bits());
    }

    #[test]
    fn generators_deterministic() {
        let p = BenchParams::default();
        let a = kratos::conv2d(&p);
        let b = kratos::conv2d(&p);
        assert_eq!(a.num_adder_bits(), b.num_adder_bits());
        assert_eq!(a.aig.len(), b.aig.len());
    }
}
