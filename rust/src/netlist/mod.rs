//! Technology-mapped netlist IR.
//!
//! This is the interchange format between technology mapping
//! ([`crate::techmap`]) and the physical flow ([`crate::pack`],
//! [`crate::place`], [`crate::route`], [`crate::timing`]).  Cells are LUTs,
//! adder bits (1-bit full adders linked into carry chains), flip-flops, and
//! I/Os; nets record their driver and sinks.  A BLIF-subset reader/writer
//! ([`blif`]) provides external interchange, and [`index`] flattens the
//! hot-path views (CSR fanout, dense drivers, combinational levelization,
//! cell→ALM/LB ownership) into cache-friendly arenas built once per
//! netlist/packing.  Structural well-formedness (pin shapes, drivers,
//! chain continuity, acyclicity) is re-verified over those arenas by
//! [`crate::check::audit_netlist`] — the check-layer contract.

pub mod blif;
pub mod index;
pub mod stats;

use std::collections::HashMap;

pub use index::{NetlistIndex, PackIndex};
pub use stats::NetlistStats;

/// Index of a [`Cell`] in [`Netlist::cells`].
pub type CellId = u32;
/// Index of a [`Net`] in [`Netlist::nets`].
pub type NetId = u32;

/// Sentinel for "no net".
pub const NO_NET: NetId = u32::MAX;

/// Kind of a mapped cell.
#[derive(Clone, Debug, PartialEq)]
pub enum CellKind {
    /// Primary input; drives `outs[0]`.
    Input,
    /// Primary output; consumes `ins[0]`.
    Output,
    /// K-input LUT. `truth` holds the function over `ins` (LSB-first,
    /// `ins[0]` is bit 0 of the row index). Up to K = 6.
    Lut { k: u8, truth: u64 },
    /// One bit of a carry chain: `ins = [a, b, cin]`, `outs = [sum, cout]`.
    /// `chain` identifies the chain; `pos` the bit position within it.
    AdderBit { chain: u32, pos: u32 },
    /// D flip-flop: `ins = [d]`, `outs = [q]`.
    Ff,
    /// Constant driver of `outs[0]`.
    Const(bool),
}

/// One mapped cell.
#[derive(Clone, Debug)]
pub struct Cell {
    pub kind: CellKind,
    pub name: String,
    pub ins: Vec<NetId>,
    pub outs: Vec<NetId>,
}

/// One net: a driver pin and fanout sinks.
#[derive(Clone, Debug, Default)]
pub struct Net {
    pub name: String,
    /// Driving (cell, output-pin index); `None` for floating nets.
    pub driver: Option<(CellId, u8)>,
    /// Sink (cell, input-pin index) pairs.
    pub sinks: Vec<(CellId, u8)>,
}

/// A mapped design.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub name: String,
    pub cells: Vec<Cell>,
    pub nets: Vec<Net>,
    pub inputs: Vec<CellId>,
    pub outputs: Vec<CellId>,
    /// Number of distinct carry chains (chain ids are `0..num_chains`).
    pub num_chains: u32,
}

impl Netlist {
    pub fn new(name: &str) -> Self {
        Netlist { name: name.to_string(), ..Default::default() }
    }

    /// Create a fresh net with an auto-generated name.
    pub fn add_net(&mut self, name: impl Into<String>) -> NetId {
        let id = self.nets.len() as NetId;
        self.nets.push(Net { name: name.into(), ..Default::default() });
        id
    }

    /// Add a cell, wiring up driver/sink bookkeeping on its nets.
    pub fn add_cell(&mut self, kind: CellKind, name: impl Into<String>,
                    ins: Vec<NetId>, outs: Vec<NetId>) -> CellId {
        let id = self.cells.len() as CellId;
        for (pin, &n) in ins.iter().enumerate() {
            if n != NO_NET {
                self.nets[n as usize].sinks.push((id, pin as u8));
            }
        }
        for (pin, &n) in outs.iter().enumerate() {
            if n != NO_NET {
                debug_assert!(self.nets[n as usize].driver.is_none(),
                              "net {} multiply driven", self.nets[n as usize].name);
                self.nets[n as usize].driver = Some((id, pin as u8));
            }
        }
        match kind {
            CellKind::Input => self.inputs.push(id),
            CellKind::Output => self.outputs.push(id),
            _ => {}
        }
        self.cells.push(Cell { kind, name: name.into(), ins, outs });
        id
    }

    /// Convenience: add a primary input and return its net.
    pub fn add_input(&mut self, name: &str) -> NetId {
        let n = self.add_net(name.to_string());
        self.add_cell(CellKind::Input, name, vec![], vec![n]);
        n
    }

    /// Convenience: add a primary output consuming `net`.
    pub fn add_output(&mut self, name: &str, net: NetId) -> CellId {
        self.add_cell(CellKind::Output, name, vec![net], vec![])
    }

    /// Number of cells of each interesting kind.
    pub fn count<F: Fn(&CellKind) -> bool>(&self, f: F) -> usize {
        self.cells.iter().filter(|c| f(&c.kind)).count()
    }

    pub fn num_luts(&self) -> usize {
        self.count(|k| matches!(k, CellKind::Lut { .. }))
    }

    pub fn num_adders(&self) -> usize {
        self.count(|k| matches!(k, CellKind::AdderBit { .. }))
    }

    pub fn num_ffs(&self) -> usize {
        self.count(|k| matches!(k, CellKind::Ff))
    }

    /// All cells of a given chain, ordered by `pos`.
    pub fn chain_cells(&self, chain: u32) -> Vec<CellId> {
        let mut v: Vec<(u32, CellId)> = self
            .cells
            .iter()
            .enumerate()
            .filter_map(|(i, c)| match c.kind {
                CellKind::AdderBit { chain: ch, pos } if ch == chain => {
                    Some((pos, i as CellId))
                }
                _ => None,
            })
            .collect();
        v.sort_unstable();
        v.into_iter().map(|(_, c)| c).collect()
    }

    /// Validate structural invariants; returns a list of human-readable
    /// violations (empty = clean). Used by tests and after every transform.
    pub fn check(&self) -> Vec<String> {
        let mut errs = Vec::new();
        for (i, c) in self.cells.iter().enumerate() {
            let (want_in, want_out): (usize, usize) = match c.kind {
                CellKind::Input => (0, 1),
                CellKind::Output => (1, 0),
                CellKind::Lut { k, .. } => (k as usize, 1),
                CellKind::AdderBit { .. } => (3, 2),
                CellKind::Ff => (1, 1),
                CellKind::Const(_) => (0, 1),
            };
            if c.ins.len() != want_in {
                errs.push(format!("cell {i} ({}) has {} ins, want {want_in}",
                                  c.name, c.ins.len()));
            }
            if c.outs.len() != want_out {
                errs.push(format!("cell {i} ({}) has {} outs, want {want_out}",
                                  c.name, c.outs.len()));
            }
            if let CellKind::Lut { k, truth } = c.kind {
                if k < 6 && k > 0 {
                    let rows = 1u64 << k;
                    if rows < 64 && (truth >> rows) != 0 {
                        errs.push(format!("cell {i} truth table wider than 2^{k}"));
                    }
                }
            }
        }
        // Net driver/sink cross-references.
        for (ni, net) in self.nets.iter().enumerate() {
            if let Some((c, pin)) = net.driver {
                let cell = &self.cells[c as usize];
                if cell.outs.get(pin as usize) != Some(&(ni as NetId)) {
                    errs.push(format!("net {ni} driver backref broken"));
                }
            }
            for &(c, pin) in &net.sinks {
                let cell = &self.cells[c as usize];
                if cell.ins.get(pin as usize) != Some(&(ni as NetId)) {
                    errs.push(format!("net {ni} sink backref broken"));
                }
            }
        }
        // Chain continuity: cout(pos) must feed cin(pos+1).
        for ch in 0..self.num_chains {
            let cells = self.chain_cells(ch);
            for w in cells.windows(2) {
                let cout = self.cells[w[0] as usize].outs[1];
                let cin = self.cells[w[1] as usize].ins[2];
                if cout != cin {
                    errs.push(format!("chain {ch} broken between {} and {}",
                                      w[0], w[1]));
                }
            }
        }
        errs
    }

    /// Map from net name to id (for tests / BLIF round-trips).
    pub fn net_by_name(&self) -> HashMap<&str, NetId> {
        self.nets
            .iter()
            .enumerate()
            .map(|(i, n)| (n.name.as_str(), i as NetId))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny netlist: 2 inputs -> LUT(AND) -> output.
    fn tiny() -> Netlist {
        let mut nl = Netlist::new("tiny");
        let a = nl.add_input("a");
        let b = nl.add_input("b");
        let y = nl.add_net("y");
        nl.add_cell(CellKind::Lut { k: 2, truth: 0b1000 }, "and", vec![a, b], vec![y]);
        nl.add_output("out_y", y);
        nl
    }

    #[test]
    fn build_and_check() {
        let nl = tiny();
        assert_eq!(nl.num_luts(), 1);
        assert_eq!(nl.inputs.len(), 2);
        assert_eq!(nl.outputs.len(), 1);
        assert!(nl.check().is_empty(), "{:?}", nl.check());
    }

    #[test]
    fn net_backrefs() {
        let nl = tiny();
        let y = nl.net_by_name()["y"];
        let net = &nl.nets[y as usize];
        assert!(net.driver.is_some());
        assert_eq!(net.sinks.len(), 1);
    }

    #[test]
    fn chain_cells_ordered() {
        let mut nl = Netlist::new("chain");
        let a0 = nl.add_input("a0");
        let b0 = nl.add_input("b0");
        let a1 = nl.add_input("a1");
        let b1 = nl.add_input("b1");
        let cin = nl.add_net("cin0");
        nl.add_cell(CellKind::Const(false), "gnd", vec![], vec![cin]);
        let s0 = nl.add_net("s0");
        let c0 = nl.add_net("c0");
        let s1 = nl.add_net("s1");
        let c1 = nl.add_net("c1");
        // Deliberately add bit 1 first to exercise ordering.
        nl.add_cell(CellKind::AdderBit { chain: 0, pos: 1 }, "fa1",
                    vec![a1, b1, c0], vec![s1, c1]);
        nl.add_cell(CellKind::AdderBit { chain: 0, pos: 0 }, "fa0",
                    vec![a0, b0, cin], vec![s0, c0]);
        nl.num_chains = 1;
        nl.add_output("o0", s0);
        nl.add_output("o1", s1);
        let cells = nl.chain_cells(0);
        assert_eq!(cells.len(), 2);
        assert!(matches!(nl.cells[cells[0] as usize].kind,
                         CellKind::AdderBit { pos: 0, .. }));
        assert!(nl.check().is_empty(), "{:?}", nl.check());
    }

    #[test]
    fn check_catches_broken_chain() {
        let mut nl = Netlist::new("bad");
        let a = nl.add_input("a");
        let b = nl.add_input("b");
        let g = nl.add_net("gnd");
        nl.add_cell(CellKind::Const(false), "gnd", vec![], vec![g]);
        let s0 = nl.add_net("s0");
        let c0 = nl.add_net("c0");
        let s1 = nl.add_net("s1");
        let c1 = nl.add_net("c1");
        nl.add_cell(CellKind::AdderBit { chain: 0, pos: 0 }, "fa0",
                    vec![a, b, g], vec![s0, c0]);
        // Bit 1 takes gnd instead of c0 -> broken chain.
        nl.add_cell(CellKind::AdderBit { chain: 0, pos: 1 }, "fa1",
                    vec![a, b, g], vec![s1, c1]);
        nl.num_chains = 1;
        assert!(!nl.check().is_empty());
    }
}
