//! A small deterministic CDCL SAT solver (the discharge engine behind
//! [`super`]'s miter cones).
//!
//! Classic conflict-driven clause learning with the three ingredients the
//! issue names and nothing speculative on top:
//!
//! * **two watched literals** per clause — propagation touches only the
//!   clauses whose watch just became false;
//! * **VSIDS-lite** branching — per-variable activity bumped on every
//!   conflict-side variable and decayed geometrically per conflict, with
//!   ties broken toward the *lowest* variable index so the decision
//!   sequence is a pure function of the CNF;
//! * **first-UIP learning** — each conflict learns the first
//!   unique-implication-point clause and backjumps to its assertion
//!   level.
//!
//! Restarts follow a fixed geometric schedule (also deterministic).  The
//! solver never panics: a malformed query degrades to `Unsat` (empty
//! clause) or `Unknown` (budget exhausted), and every internal lookup is
//! bounds-guarded.  There is no wall clock anywhere — the only resource
//! limit is the logical conflict budget, so results are bit-identical
//! across machines and worker counts (the determinism contract every
//! `check` auditor carries).

/// Variable index (0-based).
pub type Var = u32;

/// A literal: variable with a sign bit in the LSB (`var << 1 | neg`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct SLit(pub u32);

impl SLit {
    #[inline]
    pub fn new(v: Var, neg: bool) -> SLit {
        SLit(v << 1 | neg as u32)
    }

    #[inline]
    pub fn var(self) -> Var {
        self.0 >> 1
    }

    #[inline]
    pub fn is_neg(self) -> bool {
        self.0 & 1 == 1
    }

    #[inline]
    #[must_use]
    pub fn negate(self) -> SLit {
        SLit(self.0 ^ 1)
    }

    #[inline]
    fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Outcome of [`Solver::solve`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SatResult {
    /// Satisfiable; the model assigns every variable (`model[v]`).
    Sat(Vec<bool>),
    /// Proven unsatisfiable.
    Unsat,
    /// Conflict budget exhausted before a verdict.
    Unknown,
}

const NO_REASON: u32 = u32::MAX;

/// CDCL solver state.  Build with [`Solver::new`], add clauses, then
/// [`Solver::solve`] once (the solver is single-shot).
pub struct Solver {
    n_vars: usize,
    clauses: Vec<Vec<SLit>>,
    /// Per literal: indices of clauses watching it.
    watches: Vec<Vec<u32>>,
    /// Per variable: +1 true, -1 false, 0 unassigned.
    assigns: Vec<i8>,
    level: Vec<u32>,
    reason: Vec<u32>,
    trail: Vec<SLit>,
    trail_lim: Vec<usize>,
    qhead: usize,
    activity: Vec<f64>,
    var_inc: f64,
    seen: Vec<bool>,
    /// An empty or root-conflicting clause was added.
    root_unsat: bool,
}

impl Solver {
    pub fn new(n_vars: usize) -> Solver {
        Solver {
            n_vars,
            clauses: Vec::new(),
            watches: vec![Vec::new(); n_vars * 2],
            assigns: vec![0; n_vars],
            level: vec![0; n_vars],
            reason: vec![NO_REASON; n_vars],
            trail: Vec::new(),
            trail_lim: Vec::new(),
            qhead: 0,
            activity: vec![0.0; n_vars],
            var_inc: 1.0,
            seen: vec![false; n_vars],
            root_unsat: false,
        }
    }

    #[inline]
    fn value(&self, l: SLit) -> i8 {
        let a = self.assigns.get(l.var() as usize).copied().unwrap_or(0);
        if l.is_neg() {
            -a
        } else {
            a
        }
    }

    #[inline]
    fn decision_level(&self) -> usize {
        self.trail_lim.len()
    }

    fn enqueue(&mut self, l: SLit, reason: u32) {
        let v = l.var() as usize;
        if v >= self.n_vars {
            return;
        }
        self.assigns[v] = if l.is_neg() { -1 } else { 1 };
        self.level[v] = self.decision_level() as u32;
        self.reason[v] = reason;
        self.trail.push(l);
    }

    /// Add a clause.  Literals referencing variables `>= n_vars` are
    /// dropped (a caller bug that must degrade, not panic); an empty
    /// clause marks the instance root-unsatisfiable.
    pub fn add_clause(&mut self, lits: &[SLit]) {
        if self.root_unsat {
            return;
        }
        let mut cl: Vec<SLit> = lits
            .iter()
            .copied()
            .filter(|l| (l.var() as usize) < self.n_vars)
            .collect();
        cl.dedup();
        match cl.len() {
            0 => self.root_unsat = true,
            1 => match self.value(cl[0]) {
                1 => {}
                -1 => self.root_unsat = true,
                _ => self.enqueue(cl[0], NO_REASON),
            },
            _ => {
                let ci = self.clauses.len() as u32;
                self.watches[cl[0].idx()].push(ci);
                self.watches[cl[1].idx()].push(ci);
                self.clauses.push(cl);
            }
        }
    }

    /// Propagate all enqueued assignments; `Some(clause)` on conflict.
    fn propagate(&mut self) -> Option<u32> {
        while self.qhead < self.trail.len() {
            let p = self.trail[self.qhead];
            self.qhead += 1;
            // Clauses watching ¬p just lost that watch.
            let false_lit = p.negate();
            let ws = std::mem::take(&mut self.watches[false_lit.idx()]);
            let mut keep: Vec<u32> = Vec::with_capacity(ws.len());
            let mut conflict = None;
            for (wi, &ci) in ws.iter().enumerate() {
                let cii = ci as usize;
                if cii >= self.clauses.len() {
                    continue;
                }
                if self.clauses[cii].first().copied() == Some(false_lit) {
                    self.clauses[cii].swap(0, 1);
                }
                let first = self.clauses[cii][0];
                if self.value(first) == 1 {
                    keep.push(ci);
                    continue;
                }
                let mut moved = false;
                for k in 2..self.clauses[cii].len() {
                    let lk = self.clauses[cii][k];
                    if self.value(lk) != -1 {
                        self.clauses[cii].swap(1, k);
                        let new_watch = self.clauses[cii][1];
                        self.watches[new_watch.idx()].push(ci);
                        moved = true;
                        break;
                    }
                }
                if moved {
                    continue;
                }
                // Unit under the current assignment, or conflicting.
                keep.push(ci);
                if self.value(first) == -1 {
                    keep.extend_from_slice(&ws[wi + 1..]);
                    conflict = Some(ci);
                    break;
                }
                self.enqueue(first, ci);
            }
            self.watches[false_lit.idx()] = keep;
            if conflict.is_some() {
                self.qhead = self.trail.len();
                return conflict;
            }
        }
        None
    }

    fn bump(&mut self, v: usize) {
        if v >= self.activity.len() {
            return;
        }
        self.activity[v] += self.var_inc;
        if self.activity[v] > 1e100 {
            for a in &mut self.activity {
                *a *= 1e-100;
            }
            self.var_inc *= 1e-100;
        }
    }

    /// First-UIP conflict analysis: returns (learnt clause with the
    /// asserting literal first, backjump level), or `None` if the
    /// implication graph is inconsistent (a caller bug; learning anything
    /// on that path would be unsound, so the solve degrades to Unknown).
    fn analyze(&mut self, confl: u32) -> Option<(Vec<SLit>, usize)> {
        let mut learnt: Vec<SLit> = vec![SLit(0)]; // slot 0 = asserting lit
        let mut counter = 0usize;
        let mut p: Option<SLit> = None;
        let mut ci = confl as usize;
        let mut idx = self.trail.len();
        let cur = self.decision_level() as u32;
        loop {
            if ci < self.clauses.len() {
                for j in 0..self.clauses[ci].len() {
                    let q = self.clauses[ci][j];
                    if Some(q) == p {
                        continue;
                    }
                    let v = q.var() as usize;
                    if v < self.n_vars && !self.seen[v] && self.level[v] > 0 {
                        self.seen[v] = true;
                        self.bump(v);
                        if self.level[v] >= cur {
                            counter += 1;
                        } else {
                            learnt.push(q);
                        }
                    }
                }
            }
            // Walk the trail backwards to the next marked literal.
            let pl = loop {
                if idx == 0 {
                    break None;
                }
                idx -= 1;
                let t = self.trail[idx];
                if self.seen[t.var() as usize] {
                    break Some(t);
                }
            };
            let Some(pl) = pl else {
                // Unreachable when the implication graph is consistent
                // (counter tracks marked current-level literals still on
                // the trail); bail out rather than learn a bogus clause.
                self.seen.iter_mut().for_each(|s| *s = false);
                return None;
            };
            let v = pl.var() as usize;
            self.seen[v] = false;
            counter = counter.saturating_sub(1);
            if counter == 0 {
                learnt[0] = pl.negate();
                break;
            }
            if self.reason[v] == NO_REASON {
                // Decision reached with marked literals outstanding —
                // same inconsistency, same safe exit.
                self.seen.iter_mut().for_each(|s| *s = false);
                return None;
            }
            p = Some(pl);
            ci = self.reason[v] as usize;
        }
        for l in &learnt[1..] {
            let v = l.var() as usize;
            if v < self.seen.len() {
                self.seen[v] = false;
            }
        }
        // Backjump to the second-highest decision level in the clause.
        let mut bt = 0usize;
        if learnt.len() > 1 {
            let mut mi = 1;
            for k in 2..learnt.len() {
                if self.level[learnt[k].var() as usize] > self.level[learnt[mi].var() as usize] {
                    mi = k;
                }
            }
            learnt.swap(1, mi);
            bt = self.level[learnt[1].var() as usize] as usize;
        }
        Some((learnt, bt))
    }

    fn backtrack(&mut self, bt: usize) {
        while self.decision_level() > bt {
            let Some(lim) = self.trail_lim.pop() else { break };
            while self.trail.len() > lim {
                if let Some(l) = self.trail.pop() {
                    let v = l.var() as usize;
                    if v < self.n_vars {
                        self.assigns[v] = 0;
                        self.reason[v] = NO_REASON;
                    }
                }
            }
        }
        self.qhead = self.trail.len();
    }

    /// Unassigned variable of maximal activity (lowest index on ties).
    fn pick_branch(&self) -> Option<Var> {
        let mut best: Option<usize> = None;
        for v in 0..self.n_vars {
            if self.assigns[v] != 0 {
                continue;
            }
            match best {
                None => best = Some(v),
                Some(b) => {
                    if self.activity[v] > self.activity[b] {
                        best = Some(v);
                    }
                }
            }
        }
        best.map(|v| v as Var)
    }

    /// Run CDCL for at most `max_conflicts` conflicts.
    pub fn solve(&mut self, max_conflicts: u64) -> SatResult {
        if self.root_unsat {
            return SatResult::Unsat;
        }
        if self.propagate().is_some() {
            return SatResult::Unsat;
        }
        let mut conflicts = 0u64;
        let mut next_restart = 128u64;
        loop {
            match self.propagate() {
                Some(confl) => {
                    conflicts += 1;
                    if self.decision_level() == 0 {
                        return SatResult::Unsat;
                    }
                    if conflicts > max_conflicts {
                        return SatResult::Unknown;
                    }
                    let Some((learnt, bt)) = self.analyze(confl) else {
                        return SatResult::Unknown;
                    };
                    self.backtrack(bt);
                    if learnt.len() == 1 {
                        match self.value(learnt[0]) {
                            -1 => return SatResult::Unsat,
                            0 => self.enqueue(learnt[0], NO_REASON),
                            _ => {}
                        }
                    } else {
                        let ci = self.clauses.len() as u32;
                        self.watches[learnt[0].idx()].push(ci);
                        self.watches[learnt[1].idx()].push(ci);
                        let assert_lit = learnt[0];
                        self.clauses.push(learnt);
                        if self.value(assert_lit) == 0 {
                            self.enqueue(assert_lit, ci);
                        }
                    }
                    self.var_inc *= 1.0 / 0.95;
                    if conflicts >= next_restart {
                        next_restart += next_restart / 2 + 64;
                        self.backtrack(0);
                    }
                }
                None => match self.pick_branch() {
                    None => {
                        let model = self.assigns.iter().map(|&a| a == 1).collect();
                        return SatResult::Sat(model);
                    }
                    Some(v) => {
                        // Deterministic negative phase (matches the
                        // all-zero simulation baseline).
                        self.trail_lim.push(self.trail.len());
                        self.enqueue(SLit::new(v, true), NO_REASON);
                    }
                },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lit(v: Var) -> SLit {
        SLit::new(v, false)
    }

    fn nlit(v: Var) -> SLit {
        SLit::new(v, true)
    }

    #[test]
    fn trivially_sat_and_unsat() {
        let mut s = Solver::new(2);
        s.add_clause(&[lit(0), lit(1)]);
        match s.solve(1_000) {
            SatResult::Sat(m) => assert!(m[0] || m[1]),
            other => panic!("expected sat, got {other:?}"),
        }

        let mut s = Solver::new(1);
        s.add_clause(&[lit(0)]);
        s.add_clause(&[nlit(0)]);
        assert_eq!(s.solve(1_000), SatResult::Unsat);

        let mut s = Solver::new(1);
        s.add_clause(&[]);
        assert_eq!(s.solve(1_000), SatResult::Unsat);
    }

    /// Pigeonhole 4→3: classic small UNSAT that requires real search.
    #[test]
    fn pigeonhole_unsat() {
        let (pigeons, holes) = (4u32, 3u32);
        let var = |p: u32, h: u32| p * holes + h;
        let mut s = Solver::new((pigeons * holes) as usize);
        for p in 0..pigeons {
            let cl: Vec<SLit> = (0..holes).map(|h| lit(var(p, h))).collect();
            s.add_clause(&cl);
        }
        for h in 0..holes {
            for p1 in 0..pigeons {
                for p2 in p1 + 1..pigeons {
                    s.add_clause(&[nlit(var(p1, h)), nlit(var(p2, h))]);
                }
            }
        }
        assert_eq!(s.solve(1_000_000), SatResult::Unsat);
    }

    /// XOR chain with consistent parity: satisfiable, and the model found
    /// must actually satisfy every clause.
    #[test]
    fn xor_chain_model_satisfies() {
        // x0 ^ x1 = 1, x1 ^ x2 = 1, x2 ^ x3 = 0, x0 = 1.
        let mut s = Solver::new(4);
        let xor_cl = |s: &mut Solver, a: Var, b: Var, val: bool| {
            if val {
                s.add_clause(&[lit(a), lit(b)]);
                s.add_clause(&[nlit(a), nlit(b)]);
            } else {
                s.add_clause(&[lit(a), nlit(b)]);
                s.add_clause(&[nlit(a), lit(b)]);
            }
        };
        xor_cl(&mut s, 0, 1, true);
        xor_cl(&mut s, 1, 2, true);
        xor_cl(&mut s, 2, 3, false);
        s.add_clause(&[lit(0)]);
        match s.solve(10_000) {
            SatResult::Sat(m) => {
                assert!(m[0]);
                assert_ne!(m[0], m[1]);
                assert_ne!(m[1], m[2]);
                assert_eq!(m[2], m[3]);
            }
            other => panic!("expected sat, got {other:?}"),
        }
    }

    #[test]
    fn budget_exhaustion_reports_unknown() {
        // Pigeonhole 6→5 with a 1-conflict budget cannot finish.
        let (pigeons, holes) = (6u32, 5u32);
        let var = |p: u32, h: u32| p * holes + h;
        let mut s = Solver::new((pigeons * holes) as usize);
        for p in 0..pigeons {
            let cl: Vec<SLit> = (0..holes).map(|h| lit(var(p, h))).collect();
            s.add_clause(&cl);
        }
        for h in 0..holes {
            for p1 in 0..pigeons {
                for p2 in p1 + 1..pigeons {
                    s.add_clause(&[nlit(var(p1, h)), nlit(var(p2, h))]);
                }
            }
        }
        assert_eq!(s.solve(1), SatResult::Unknown);
    }

    #[test]
    fn deterministic_across_runs() {
        let build = || {
            let mut s = Solver::new(6);
            s.add_clause(&[lit(0), lit(1), lit(2)]);
            s.add_clause(&[nlit(0), lit(3)]);
            s.add_clause(&[nlit(1), lit(4)]);
            s.add_clause(&[nlit(2), lit(5)]);
            s.add_clause(&[nlit(3), nlit(4), nlit(5)]);
            s
        };
        let a = build().solve(10_000);
        let b = build().solve(10_000);
        assert_eq!(a, b);
        assert!(matches!(a, SatResult::Sat(_)));
    }
}
