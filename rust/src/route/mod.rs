//! PathFinder negotiated-congestion routing over the shared
//! routing-resource graph ([`crate::rrg`]).
//!
//! The RR abstraction (node layout, CSR adjacency, pin connectivity, the
//! congestion cost formula) lives in [`crate::rrg`]; this module owns the
//! negotiation loop.  Each iteration is *deterministic parallel
//! negotiated congestion* in three phases:
//!
//! 1. rip up every congested net in fixed net order (serial),
//! 2. re-route the ripped-up nets by A*, in fixed contiguous *waves* of
//!    [`WAVE`] nets: each wave routes against a read-only snapshot of the
//!    cost state, sharded across `RouteOpts::jobs` workers
//!    ([`crate::coordinator::parallel_indexed_with`], each worker reusing
//!    one set of search arrays), then commits its occupancy in net order
//!    before the next wave starts,
//! 3. bump history costs on overused nodes (serial reduction).
//!
//! Wave boundaries depend only on the work list — never on the worker
//! count — and routing a net is a pure function of (wave snapshot, net),
//! so results are bit-identical for any `jobs` value — see
//! `rust/tests/route_parallel.rs`.  The wave size trades negotiation
//! fidelity (small waves see fresher occupancy, converging in fewer
//! iterations, like VPR's sequential router) against available
//! parallelism; measurements on synthetic instances put the total-work
//! overhead of 32-net waves at ~1.5x the sequential router versus ~3x for
//! whole-iteration snapshots.  Produces per-sink routed path lengths (for
//! the post-route STA) and the channel-utilization histogram of Fig. 8.

use std::collections::{BinaryHeap, HashMap, HashSet};

use crate::arch::device::Loc;
use crate::arch::Arch;
use crate::coordinator::parallel_indexed_with;
use crate::netlist::{CellId, NetId};
use crate::place::cost::{NetModel, Term};
use crate::place::Placement;
use crate::rrg::{self, CostState, RrGraph, NODE_CAP};

/// VPR's astar_fac: inflate the admissible heuristic for a large
/// search-space cut at bounded routing-cost suboptimality.
const ASTAR_FAC: f64 = 1.3;

/// Nets routed per negotiation wave (see module docs).  Fixed — never
/// derived from the worker count — so wave composition, and therefore the
/// routing result, is identical for any `RouteOpts::jobs`.
pub const WAVE: usize = 32;

/// Fraction of the base cost a fully critical net is forgiven (the
/// timing-driven first step: critical nets see cheaper, therefore more
/// direct, wiring while congestion and history terms stay shared).
const CRIT_BASE_DISCOUNT: f64 = 0.5;

/// Router options.
#[derive(Clone, Debug)]
pub struct RouteOpts {
    pub max_iters: usize,
    /// Initial present-congestion factor and its per-iteration growth.
    pub pres_fac0: f64,
    pub pres_mult: f64,
    /// History cost increment per overused node per iteration.
    pub hist_fac: f64,
    /// Worker threads sharding the per-net A* searches (1 = serial; the
    /// result is bit-identical for any value).
    pub jobs: usize,
    /// Optional per-net criticality in [0, 1], indexed by [`NetId`]
    /// (typically [`crate::timing::TimingReport::net_crit`]).  When
    /// non-empty, a net's PathFinder *base* cost is scaled by
    /// `1 - CRIT_BASE_DISCOUNT * crit`, so critical nets prefer direct
    /// paths and concede congested ones to slack-rich nets.  Empty (the
    /// default) multiplies by exactly 1.0 — bit-identical to the
    /// timing-oblivious router.
    pub net_crit: Vec<f64>,
}

impl Default for RouteOpts {
    fn default() -> Self {
        // Snapshot-based negotiation (all ripped-up nets re-route against
        // the frozen iteration-start costs, as in the original PathFinder
        // formulation) can take a few more iterations than VPR's
        // sequential-commit variant to shake out symmetric conflicts, so
        // the cap carries headroom; converged runs exit early regardless.
        RouteOpts {
            max_iters: 64,
            pres_fac0: 0.5,
            pres_mult: 1.6,
            hist_fac: 0.5,
            jobs: 1,
            net_crit: Vec::new(),
        }
    }
}

/// Routing result.
#[derive(Clone, Debug)]
pub struct Routing {
    pub success: bool,
    pub iterations: usize,
    /// Per external net: per sink terminal, wire-hop count of its path.
    pub sink_hops: Vec<Vec<(Term, usize)>>,
    /// Occupancy / capacity per channel node (for the Fig. 8 histogram).
    pub channel_util: Vec<f64>,
    /// Total wirelength in hops.
    pub wirelength: usize,
    /// Nodes still overused at exit (0 on success).
    pub overused: usize,
    /// Debug: overused node descriptors (dir, x, y, track, occupancy).
    pub overused_nodes: Vec<(usize, usize, usize, usize, u16)>,
    /// Debug: per-net routed node ids.
    pub net_nodes: Vec<Vec<usize>>,
}

impl Routing {
    /// Fig. 8 histogram: fraction of channel segments per utilization bin.
    pub fn util_histogram(&self, bins: usize) -> Vec<f64> {
        let mut h = vec![0.0; bins];
        if self.channel_util.is_empty() {
            return h;
        }
        for &u in &self.channel_util {
            let b = ((u * bins as f64) as usize).min(bins - 1);
            h[b] += 1.0;
        }
        let total: f64 = h.iter().sum();
        h.iter_mut().for_each(|v| *v /= total);
        h
    }
}

#[derive(PartialEq)]
struct QItem {
    prio: f64,
    cost: f64,
    node: usize,
}
impl Eq for QItem {}
impl Ord for QItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.prio.partial_cmp(&self.prio).unwrap_or(std::cmp::Ordering::Equal)
    }
}
impl PartialOrd for QItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-worker A* search state, reused across the nets a worker routes.
/// Reset between searches via the `touched` list, so a search's outcome
/// never depends on which worker (or in which order) it ran.
struct AStarScratch {
    cost: Vec<f64>,
    prev: Vec<usize>,
    touched: Vec<usize>,
}

impl AStarScratch {
    fn new(n_nodes: usize) -> AStarScratch {
        AStarScratch {
            cost: vec![f64::INFINITY; n_nodes],
            prev: vec![usize::MAX; n_nodes],
            touched: Vec::new(),
        }
    }
}

/// Checks a scratch out of a shared pool for the duration of one wave and
/// returns it on drop, so the O(n_nodes) arrays are allocated at most
/// `jobs` times per `route()` call instead of per wave.  Reuse is safe
/// because every search resets exactly the entries its predecessors
/// touched before reading them.
struct ScratchLease<'a> {
    pool: &'a std::sync::Mutex<Vec<AStarScratch>>,
    scratch: Option<AStarScratch>,
}

impl<'a> ScratchLease<'a> {
    fn take(pool: &'a std::sync::Mutex<Vec<AStarScratch>>, n_nodes: usize) -> ScratchLease<'a> {
        let s = pool
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| AStarScratch::new(n_nodes));
        ScratchLease { pool, scratch: Some(s) }
    }
}

impl Drop for ScratchLease<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.scratch.take() {
            self.pool.lock().unwrap().push(s);
        }
    }
}

/// Route one net against a frozen cost snapshot.  Pure in
/// (graph, snapshot, pres_fac, net, weight): no shared mutable state.
/// `weight` scales the per-node cost this net perceives (1.0 = neutral;
/// see [`RouteOpts::net_crit`]).  Returns the net's committed node set
/// (sorted, deduped) and per-sink hop counts.
#[allow(clippy::too_many_arguments)]
fn route_net<F: Fn(Term) -> Loc>(
    graph: &RrGraph,
    costs: &CostState,
    pres_fac: f64,
    ni: usize,
    terms: &[Term],
    term_loc: &F,
    arch: &Arch,
    weight: f64,
    scratch: &mut AStarScratch,
) -> (Vec<usize>, Vec<(Term, usize)>) {
    let src_loc = term_loc(terms[0]);
    let src_nodes = graph.pin_nodes(src_loc, arch.routing.fc_out, 17 + 131 * ni as u64);

    // Route tree as a set of nodes with hop-distance from source.  Seeds
    // (source track taps) are search entry points but only nodes actually
    // used by a sink path get committed.
    let mut tree: HashMap<usize, usize> = HashMap::new(); // node -> hops
    let mut used: Vec<usize> = Vec::new();
    for &id in &src_nodes {
        tree.insert(id, 0);
    }
    let mut sink_hops: Vec<(Term, usize)> = Vec::with_capacity(terms.len().saturating_sub(1));

    for &sink in &terms[1..] {
        let dst_loc = term_loc(sink);
        let dst_nodes = graph.pin_nodes(dst_loc, arch.routing.fc_in, 71 + 131 * ni as u64);
        let is_target: HashSet<usize> = dst_nodes.iter().copied().collect();
        let (tx, ty) = (dst_loc.x as usize, dst_loc.y as usize);

        // Reset the search arrays from the previous sink.
        for &n in &scratch.touched {
            scratch.cost[n] = f64::INFINITY;
            scratch.prev[n] = usize::MAX;
        }
        scratch.touched.clear();

        // A* from the current tree.
        let mut heap: BinaryHeap<QItem> = BinaryHeap::new();
        let mut seeds: Vec<(usize, usize)> = tree.iter().map(|(&n, &h)| (n, h)).collect();
        seeds.sort_unstable(); // deterministic A* tie-breaking
        for (n, hops) in seeds {
            // Fresh source taps pay their own congestion cost (otherwise a
            // net would happily start on an occupied tap it never
            // perceives); nodes already on this net's tree re-enter free.
            let entry = if hops == 0 { weight * costs.node_cost(n, pres_fac) } else { 0.0 };
            scratch.cost[n] = entry;
            scratch.prev[n] = usize::MAX;
            scratch.touched.push(n);
            heap.push(QItem { prio: entry + graph.heur(n, tx, ty), cost: entry, node: n });
        }

        let mut found = usize::MAX;
        while let Some(QItem { cost, node, .. }) = heap.pop() {
            if cost > scratch.cost[node] {
                continue;
            }
            if is_target.contains(&node) {
                found = node;
                break;
            }
            for &nb in graph.neighbors(node) {
                let nid = nb as usize;
                let nc = cost + weight * costs.node_cost(nid, pres_fac);
                if nc < scratch.cost[nid] {
                    if scratch.cost[nid].is_infinite() && scratch.prev[nid] == usize::MAX {
                        scratch.touched.push(nid);
                    }
                    scratch.cost[nid] = nc;
                    scratch.prev[nid] = node;
                    heap.push(QItem {
                        prio: nc + ASTAR_FAC * graph.heur(nid, tx, ty),
                        cost: nc,
                        node: nid,
                    });
                }
            }
        }

        if found == usize::MAX {
            // Unroutable sink this iteration; count a distance estimate and
            // keep going (pressure will reshape other nets).
            sink_hops.push((sink, (src_loc.dist(dst_loc) as usize).max(1)));
            continue;
        }
        // Walk back, add path to tree.
        let mut path = Vec::new();
        let mut cur = found;
        while cur != usize::MAX && !tree.contains_key(&cur) {
            path.push(cur);
            cur = scratch.prev[cur];
        }
        let base_hops = if cur == usize::MAX { 0 } else { tree[&cur] };
        // The attachment node is used (it may be a fresh seed tap).
        if cur != usize::MAX {
            used.push(cur);
        }
        let hops = base_hops + path.len();
        sink_hops.push((sink, hops));
        for (off, &n) in path.iter().rev().enumerate() {
            tree.insert(n, base_hops + off + 1);
            used.push(n);
        }
    }

    used.sort_unstable();
    used.dedup();
    (used, sink_hops)
}

/// Route a placed design.
pub fn route(
    model: &NetModel,
    placement: &Placement,
    arch: &Arch,
    opts: &RouteOpts,
) -> Routing {
    let device = &placement.device;
    let graph = RrGraph::build(device, arch);
    let n_nodes = graph.num_nodes();

    let term_loc = |t: Term| -> Loc {
        match t {
            Term::Lb(i) => placement.lb_loc[i],
            Term::Io(c) => placement.io_loc[&c],
        }
    };

    // Per-net terminals (source first).
    let nets: Vec<(NetId, Vec<Term>)> = model
        .nets
        .iter()
        .map(|en| (en.net, en.terms.clone()))
        .collect();

    // Optional timing-driven base-cost weights (see RouteOpts::net_crit).
    // An empty criticality vector yields exactly 1.0 everywhere, which
    // multiplies out bit-identically to the unweighted router.
    let net_weight: Vec<f64> = nets
        .iter()
        .map(|&(nid, _)| {
            let crit = opts
                .net_crit
                .get(nid as usize)
                .copied()
                .unwrap_or(0.0)
                .clamp(0.0, 1.0);
            1.0 - CRIT_BASE_DISCOUNT * crit
        })
        .collect();

    let mut costs = CostState::new(n_nodes);
    // Per net: routed node set (tree) and per-sink paths.
    let mut net_nodes: Vec<Vec<usize>> = vec![Vec::new(); nets.len()];
    let mut sink_hops: Vec<Vec<(Term, usize)>> = vec![Vec::new(); nets.len()];

    let mut pres_fac = opts.pres_fac0;
    let mut iterations = 0;
    let mut success = false;

    // Shared A* scratch pool: at most `jobs` sets of search arrays are
    // ever allocated, leased per wave and reused across waves/iterations.
    let scratch_pool: std::sync::Mutex<Vec<AStarScratch>> = std::sync::Mutex::new(Vec::new());

    for iter in 0..opts.max_iters {
        iterations = iter + 1;
        // Phase 1 — rip-up (serial, fixed order).  First iteration routes
        // everything; later iterations rip up and re-route only nets
        // touching overused nodes (VPR's incremental rip-up — the bulk of
        // nets keep their legal routes).
        let work: Vec<usize> = if iter == 0 {
            (0..nets.len()).collect()
        } else {
            (0..nets.len())
                .filter(|&ni| net_nodes[ni].iter().any(|&n| costs.overused(n)))
                .collect()
        };
        for &ni in &work {
            for &n in &net_nodes[ni] {
                costs.occ[n] = costs.occ[n].saturating_sub(1);
            }
            net_nodes[ni].clear();
            sink_hops[ni].clear();
        }

        // Phase 2 — route the ripped-up nets in fixed waves: each wave
        // runs against the frozen cost snapshot (sharded across workers
        // with per-worker search scratch), then commits occupancy in net
        // order before the next wave sees the graph.
        for wave in work.chunks(WAVE) {
            let costs_ref = &costs;
            let graph_ref = &graph;
            let nets_ref = &nets;
            let weight_ref = &net_weight;
            let term_loc_ref = &term_loc;
            let pool_ref = &scratch_pool;
            // Small waves (the long tail of late, lightly-congested
            // iterations) run on the calling thread: spawning workers for
            // a handful of nets costs more than it saves, and the result
            // is identical either way (worker count is unobservable).
            let wave_jobs = if wave.len() < 8 { 1 } else { opts.jobs.max(1) };
            let routed: Vec<(Vec<usize>, Vec<(Term, usize)>)> = parallel_indexed_with(
                wave.len(),
                wave_jobs,
                || ScratchLease::take(pool_ref, n_nodes),
                |lease, wi| {
                    let ni = wave[wi];
                    route_net(
                        graph_ref,
                        costs_ref,
                        pres_fac,
                        ni,
                        &nets_ref[ni].1,
                        term_loc_ref,
                        arch,
                        weight_ref[ni],
                        lease.scratch.as_mut().expect("scratch held for lease lifetime"),
                    )
                },
            );
            for ((used, hops), &ni) in routed.into_iter().zip(wave.iter()) {
                for &n in &used {
                    costs.occ[n] += 1;
                }
                net_nodes[ni] = used;
                sink_hops[ni] = hops;
            }
        }

        // Phase 3 — history accumulation on whatever is still overused.
        let overused = costs.bump_history(opts.hist_fac);
        if overused == 0 {
            success = true;
            break;
        }
        pres_fac *= opts.pres_mult;
    }

    let overused = costs.occ.iter().filter(|&&o| o as f64 > NODE_CAP).count();
    let overused_nodes: Vec<(usize, usize, usize, usize, u16)> = costs
        .occ
        .iter()
        .enumerate()
        .filter(|&(_, &o)| o as f64 > NODE_CAP)
        .map(|(id, &o)| {
            let (d, x, y, t) = graph.decode(id);
            (d, x, y, t, o)
        })
        .collect();

    // Channel utilization: average occupancy per channel segment (all W
    // tracks of one direction at one grid point form a "channel").
    let mut channel_util = Vec::with_capacity(2 * graph.width * graph.height);
    for dir in 0..2 {
        for y in 0..graph.height {
            for x in 0..graph.width {
                let used: usize = (0..graph.tracks)
                    .filter(|&t| costs.occ[graph.node_id(dir, x, y, t)] > 0)
                    .count();
                channel_util.push(used as f64 / graph.tracks as f64);
            }
        }
    }

    let wirelength = costs.occ.iter().map(|&o| o as usize).sum();

    Routing { success, iterations, sink_hops, channel_util, wirelength, overused, overused_nodes, net_nodes }
}

/// Per-net, per-sink routed delays for post-route STA.
pub fn routed_net_delay<'a>(
    routing: &'a Routing,
    model: &'a NetModel,
    arch: &'a Arch,
) -> impl Fn(NetId, CellId, u8) -> f64 + Sync + 'a {
    // net -> (ExtNet index) for lookup.
    let mut by_net: HashMap<NetId, usize> = HashMap::new();
    for (i, en) in model.nets.iter().enumerate() {
        by_net.insert(en.net, i);
    }
    move |net: NetId, sink: CellId, _pin: u8| -> f64 {
        let Some(&i) = by_net.get(&net) else { return 0.0 };
        // Per-sink routed hops: the sink cell's terminal identifies which
        // branch of the route tree it rides. Cells without a terminal
        // (intra-LB) and IO sinks fall back to the worst branch.
        let hops = match model.term_of_cell(sink) {
            Some(t) => routing.sink_hops[i]
                .iter()
                .find(|&&(st, _)| st == t)
                .map(|&(_, h)| h)
                .unwrap_or_else(|| {
                    routing.sink_hops[i].iter().map(|&(_, h)| h).max().unwrap_or(0)
                }),
            None => routing.sink_hops[i].iter().map(|&(_, h)| h).max().unwrap_or(0),
        };
        if hops == 0 {
            return 0.0;
        }
        rrg::hop_delay(arch, hops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Arch, ArchVariant};
    use crate::pack::{pack, PackOpts};
    use crate::place::{place, PlaceOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn routed(w: usize) -> (Routing, NetModel, Arch) {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", w);
        let y = c.pi_bus("y", w);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() });
        let mut model = NetModel::build(&nl, &packing);
        model.set_weights(&[], false);
        let r = route(&model, &pl, &arch, &RouteOpts::default());
        (r, model, arch)
    }

    #[test]
    fn routes_small_multiplier() {
        let (r, model, _) = routed(5);
        assert!(r.success, "unrouted after {} iters ({} overused)", r.iterations, r.overused);
        assert_eq!(r.sink_hops.len(), model.num_nets());
        // Every sink of every net has a path.
        for (i, en) in model.nets.iter().enumerate() {
            assert_eq!(r.sink_hops[i].len(), en.terms.len() - 1);
        }
        assert!(r.wirelength > 0);
    }

    #[test]
    fn histogram_normalized() {
        let (r, _, _) = routed(5);
        let h = r.util_histogram(10);
        assert_eq!(h.len(), 10);
        let sum: f64 = h.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tight_channel_increases_congestion() {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 6);
        let y = c.pi_bus("y", 6);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let mut arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() });
        let mut model = NetModel::build(&nl, &packing);
        model.set_weights(&[], false);
        arch.routing.channel_width = 48;
        let wide = route(&model, &pl, &arch, &RouteOpts::default());
        arch.routing.channel_width = 12;
        let narrow = route(&model, &pl, &arch, &RouteOpts::default());
        let mean_u = |r: &Routing| {
            r.channel_util.iter().sum::<f64>() / r.channel_util.len() as f64
        };
        assert!(mean_u(&narrow) > mean_u(&wide));
    }

    /// Timing-driven base-cost weights: zero criticalities are exactly the
    /// unweighted router, and real criticalities still converge and stay
    /// deterministic across worker counts.
    #[test]
    fn criticality_weights_neutral_and_deterministic() {
        let (base, model, arch) = routed(5);
        // All-zero criticality == weight 1.0 everywhere == baseline.
        let zeros = RouteOpts { net_crit: vec![0.0; 4096], ..Default::default() };
        // Re-derive placement identically to `routed` for the comparison.
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 5);
        let y = c.pi_bus("y", 5);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() });
        let r0 = route(&model, &pl, &arch, &zeros);
        assert_eq!(r0.wirelength, base.wirelength);
        assert_eq!(r0.net_nodes, base.net_nodes);
        // Weighted routing: deterministic for any job count and converges.
        let rpt = crate::timing::sta(&nl, &packing, &arch, |_, _, _| 150.0);
        let weighted = |jobs: usize| {
            route(&model, &pl, &arch,
                  &RouteOpts { jobs, net_crit: rpt.net_crit.clone(), ..Default::default() })
        };
        let w1 = weighted(1);
        assert!(w1.success, "weighted routing failed to converge");
        let w4 = weighted(4);
        assert_eq!(w1.net_nodes, w4.net_nodes);
        assert_eq!(w1.iterations, w4.iterations);
        assert_eq!(w1.wirelength, w4.wirelength);
    }
}
