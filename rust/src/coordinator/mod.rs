//! Experiment coordinator: the scoped-thread work-queue runner every
//! parallel stage of the harness shares.
//!
//! The offline environment has no tokio/rayon, so [`parallel_indexed`] is
//! a hand-rolled scoped-thread pool over an atomic job counter: results
//! land in submission order, worker panics propagate, and determinism is
//! preserved because jobs carry their own seeds (no shared RNG).
//!
//! The legacy [`Job`]/[`run_jobs`] API is kept for sweep callers and is
//! now backed by the experiment engine's process-wide
//! [`ArtifactCache`](crate::flow::engine::ArtifactCache): repeated sweeps
//! over the same benchmarks (e.g. a baseline pass followed by a DD5 pass)
//! map each circuit once and pack once per (circuit, variant).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;

use crate::arch::ArchVariant;
use crate::bench_suites::Benchmark;
use crate::flow::engine::{run_benchmark_cached, ArtifactCache};
use crate::flow::{FlowOpts, FlowResult};

/// One experiment job.
pub struct Job {
    pub bench: Benchmark,
    pub variant: ArchVariant,
    pub opts: FlowOpts,
}

/// Run `f(0)..f(n-1)` on `workers` scoped threads over an atomic work
/// queue; results are returned in index order.  `workers <= 1` runs
/// serially on the calling thread.  A panicking job propagates the panic
/// when the scope joins.
pub fn parallel_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_indexed_with(n, workers, || (), |_state, i| f(i))
}

/// [`parallel_indexed`] with per-worker scratch state: each worker builds
/// one `S` via `init` and reuses it across every job it pulls (the router
/// reuses A* search arrays this way instead of reallocating per net).
/// Jobs must not let results depend on the scratch's history — `f` has to
/// be a pure function of `i` once the scratch is reset — so that which
/// worker runs a job is unobservable and results stay deterministic for
/// any worker count.
pub fn parallel_indexed_with<S, T, I, F>(n: usize, workers: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(&mut state, i);
                    *slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker died before finishing job"))
        .collect()
}

/// Run a *levelized* job schedule on persistent workers.  Wave `w`
/// consists of the job indices `offsets[w]..offsets[w + 1]`; every job of
/// a wave completes (and its writes become visible — the inter-wave
/// barrier synchronizes) before any job of wave `w + 1` starts.  Jobs
/// write their results themselves, through disjoint slots or atomics the
/// caller owns — that is what lets one thread scope span all waves
/// instead of paying a spawn/join per wave, which is the difference
/// between profit and loss on the shallow-but-many levels of the STA and
/// mapper schedules.
///
/// Determinism contract: `f(state, i)` must be a pure function of `i`
/// (plus wave-ordered prior writes) once the scratch is reset, exactly as
/// for [`parallel_indexed_with`] — which worker runs a job, and the order
/// of jobs within one wave, are unobservable.
///
/// `workers <= 1` runs every wave serially on the calling thread.  A
/// panicking job poisons the pool (remaining work is skipped, all workers
/// drain their barriers) and the panic is re-raised on the caller.
pub fn parallel_waves_with<S, I, F>(offsets: &[usize], workers: usize, init: I, f: F)
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    let waves = offsets.len().saturating_sub(1);
    if waves == 0 {
        return;
    }
    let total = offsets[waves];
    let workers = workers.max(1).min(total.max(1));
    if workers <= 1 {
        let mut state = init();
        for w in 0..waves {
            for i in offsets[w]..offsets[w + 1] {
                f(&mut state, i);
            }
        }
        return;
    }
    let counters: Vec<AtomicUsize> = (0..waves).map(|_| AtomicUsize::new(0)).collect();
    let poisoned = std::sync::atomic::AtomicBool::new(false);
    // First panic payload (from init or a job), re-raised on the caller.
    let panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    let record_panic = |e: Box<dyn std::any::Any + Send>| {
        poisoned.store(true, Ordering::Release);
        let mut slot = panic_payload.lock().unwrap();
        if slot.is_none() {
            *slot = Some(e);
        }
    };
    let barrier = std::sync::Barrier::new(workers);
    thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                // Trap panics (from init and jobs alike) so no worker
                // abandons the barrier protocol — a vanished participant
                // would deadlock the rest.  The caller re-raises after
                // the join.
                let mut state =
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(&init)) {
                        Ok(st) => Some(st),
                        Err(e) => {
                            record_panic(e);
                            None
                        }
                    };
                for w in 0..waves {
                    let (lo, hi) = (offsets[w], offsets[w + 1]);
                    while let Some(st) = state.as_mut() {
                        if poisoned.load(Ordering::Acquire) {
                            break;
                        }
                        let i = lo + counters[w].fetch_add(1, Ordering::Relaxed);
                        if i >= hi {
                            break;
                        }
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            f(st, i)
                        }));
                        if let Err(e) = r {
                            record_panic(e);
                            break;
                        }
                    }
                    barrier.wait();
                }
            });
        }
    });
    if let Some(e) = panic_payload.into_inner().unwrap() {
        std::panic::resume_unwind(e);
    }
}

/// Run all jobs on `workers` threads; results in submission order.
/// Results are bit-identical to serial `flow::run_benchmark` calls.
pub fn run_jobs(jobs: Vec<Job>, workers: usize) -> Vec<FlowResult> {
    let cache = ArtifactCache::global();
    parallel_indexed(jobs.len(), workers, |i| {
        let j = &jobs[i];
        run_benchmark_cached(&cache, &j.bench, j.variant, &j.opts)
    })
}

/// Number of workers: respects DDUTY_WORKERS, else available parallelism.
pub fn default_workers() -> usize {
    if let Ok(w) = std::env::var("DDUTY_WORKERS") {
        if let Ok(n) = w.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suites::{vtr_suite, BenchParams};

    #[test]
    fn jobs_preserve_order_and_complete() {
        let params = BenchParams::default();
        let suite = vtr_suite(&params);
        let opts = FlowOpts {
            seeds: vec![1],
            place_effort: 0.05,
            route: false,
            ..Default::default()
        };
        let jobs: Vec<Job> = suite[..3]
            .iter()
            .map(|b| Job { bench: b.clone(), variant: ArchVariant::Baseline, opts: opts.clone() })
            .collect();
        let names: Vec<String> = jobs.iter().map(|j| j.bench.name.clone()).collect();
        let results = run_jobs(jobs, 2);
        assert_eq!(results.len(), 3);
        for (r, n) in results.iter().zip(&names) {
            assert_eq!(&r.name, n);
        }
    }

    #[test]
    fn single_worker_sequential_path() {
        let params = BenchParams::default();
        let suite = vtr_suite(&params);
        let opts = FlowOpts { seeds: vec![1], place_effort: 0.05, route: false, ..Default::default() };
        let jobs = vec![Job {
            bench: suite[0].clone(),
            variant: ArchVariant::Dd5,
            opts,
        }];
        let results = run_jobs(jobs, 1);
        assert_eq!(results.len(), 1);
    }

    #[test]
    fn parallel_indexed_with_reuses_worker_state() {
        // Scratch counts jobs per worker; results must not depend on it.
        let out = parallel_indexed_with(
            50,
            3,
            || 0usize,
            |seen, i| {
                *seen += 1;
                i * 2
            },
        );
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
        // Serial path shares one state across all jobs.
        let serial = parallel_indexed_with(4, 1, || Vec::new(), |s: &mut Vec<usize>, i| {
            s.push(i);
            s.len()
        });
        assert_eq!(serial, vec![1, 2, 3, 4]);
    }

    #[test]
    fn parallel_waves_respect_wave_barriers() {
        use std::sync::atomic::AtomicU64;
        // Job i of wave w doubles the value its wave-(w-1) counterpart
        // wrote: any barrier violation would read a stale value.
        let n = 40usize;
        let slots: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(1)).collect();
        let offsets = [0, n, 2 * n, 3 * n];
        parallel_waves_with(&offsets, 4, || (), |_, i| {
            let j = i % n;
            if i < n {
                slots[j].store(j as u64 + 1, Ordering::Relaxed);
            } else {
                let prev = slots[j].load(Ordering::Relaxed);
                slots[j].store(prev * 2, Ordering::Relaxed);
            }
        });
        for (j, s) in slots.iter().enumerate() {
            assert_eq!(s.load(Ordering::Relaxed), (j as u64 + 1) * 4);
        }
        // Serial path gives the identical result.
        let serial: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(1)).collect();
        parallel_waves_with(&offsets, 1, || (), |_, i| {
            let j = i % n;
            if i < n {
                serial[j].store(j as u64 + 1, Ordering::Relaxed);
            } else {
                let prev = serial[j].load(Ordering::Relaxed);
                serial[j].store(prev * 2, Ordering::Relaxed);
            }
        });
        for (a, b) in slots.iter().zip(serial.iter()) {
            assert_eq!(a.load(Ordering::Relaxed), b.load(Ordering::Relaxed));
        }
        // Degenerate shapes are no-ops.
        parallel_waves_with(&[], 4, || (), |_: &mut (), _| unreachable!());
        parallel_waves_with(&[0], 4, || (), |_: &mut (), _| unreachable!());
        parallel_waves_with(&[0, 0, 0], 4, || (), |_: &mut (), _| unreachable!());
    }

    /// A job panic propagates its original payload to the caller (no
    /// deadlocked barrier, no swallowed message).
    #[test]
    #[should_panic(expected = "boom")]
    fn parallel_waves_propagate_worker_panics() {
        parallel_waves_with(&[0, 64], 4, || (), |_, i| {
            if i == 13 {
                panic!("boom");
            }
        });
    }

    /// An init() panic must not deadlock the barrier protocol either.
    #[test]
    #[should_panic(expected = "init boom")]
    fn parallel_waves_propagate_init_panics() {
        use std::sync::atomic::AtomicUsize;
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        parallel_waves_with(
            &[0, 64],
            4,
            || {
                if CALLS.fetch_add(1, Ordering::Relaxed) == 1 {
                    panic!("init boom");
                }
            },
            |_, _| {},
        );
    }

    #[test]
    fn parallel_indexed_orders_and_covers() {
        let out = parallel_indexed(97, 4, |i| i * i);
        assert_eq!(out.len(), 97);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
        // Degenerate shapes.
        assert!(parallel_indexed(0, 4, |i| i).is_empty());
        assert_eq!(parallel_indexed(1, 8, |i| i + 1), vec![1]);
    }
}
