//! End-to-end CAD flow orchestration: synth -> map -> pack -> place ->
//! route -> STA, with multi-seed averaging (the paper runs 3 seeds per
//! experiment) and the metric set every table/figure consumes.

use crate::arch::device::Device;
use crate::arch::{Arch, ArchVariant};
use crate::bench_suites::Benchmark;
use crate::netlist::{Netlist, NetlistStats};
use crate::pack::{pack, PackOpts, Packing, Unrelated};
use crate::place::{place, PlaceOpts};
use crate::route::{route, routed_net_delay, RouteOpts, Routing};
use crate::synth::Circuit;
use crate::techmap::{map_circuit, MapOpts};
use crate::timing::sta;
use crate::util::stats::mean;

/// Flow options.
#[derive(Clone, Debug)]
pub struct FlowOpts {
    pub seeds: Vec<u64>,
    pub place_effort: f64,
    pub unrelated: Unrelated,
    pub route: bool,
    pub use_kernel: bool,
    /// Fixed device (Table IV stress); `None` auto-sizes per design.
    pub device: Option<Device>,
    pub channel_width: Option<u16>,
}

impl Default for FlowOpts {
    fn default() -> Self {
        FlowOpts {
            seeds: vec![1, 2, 3],
            place_effort: 0.5,
            unrelated: Unrelated::Auto,
            route: true,
            use_kernel: false,
            device: None,
            channel_width: None,
        }
    }
}

/// Metrics of one flow run (averaged over seeds).
#[derive(Clone, Debug)]
pub struct FlowResult {
    pub name: String,
    pub variant: ArchVariant,
    pub luts: usize,
    pub adder_bits: usize,
    pub alms: usize,
    pub lbs: usize,
    pub concurrent_luts: usize,
    /// ALM area in MWTA (alms x per-variant ALM area — the paper's "Total
    /// ALM Area" of Table IV).
    pub alm_area_mwta: f64,
    /// Critical path delay, ns (post-route when routed).
    pub cpd_ns: f64,
    /// Area-delay product (MWTA x ns).
    pub adp: f64,
    pub fmax_mhz: f64,
    pub routed_ok: bool,
    pub route_iters: f64,
    /// Channel utilization samples (last seed) for Fig. 8.
    pub channel_util: Vec<f64>,
    pub dedup_hits: usize,
}

/// Run the mapped portion once (deterministic), then place/route per seed.
pub fn run_flow(circ: &Circuit, arch: &Arch, opts: &FlowOpts) -> FlowResult {
    let nl = map_circuit(circ, &MapOpts::default());
    run_flow_mapped(&circ.name, &nl, arch, opts, circ.dedup_hits)
}

/// Flow from an already-mapped netlist.
pub fn run_flow_mapped(
    name: &str,
    nl: &Netlist,
    arch: &Arch,
    opts: &FlowOpts,
    dedup_hits: usize,
) -> FlowResult {
    let mut arch = arch.clone();
    if let Some(w) = opts.channel_width {
        arch.routing.channel_width = w;
    }
    let packing = pack(nl, &arch, &PackOpts { unrelated: opts.unrelated });
    let _stats = NetlistStats::of(nl);

    let mut cpds = Vec::new();
    let mut iters = Vec::new();
    let mut routed_ok = true;
    let mut channel_util = Vec::new();

    for &seed in &opts.seeds {
        let pl = place(
            nl,
            &packing,
            &arch,
            &PlaceOpts {
                seed,
                effort: opts.place_effort,
                timing_driven: true,
                use_kernel: opts.use_kernel,
                device: opts.device.clone(),
            },
        );
        if opts.route {
            let mut model = crate::place::cost::NetModel::build(nl, &packing);
            model.set_weights(&[], false);
            let r: Routing = route(&model, &pl, &arch, &RouteOpts::default());
            routed_ok &= r.success;
            iters.push(r.iterations as f64);
            let delay = routed_net_delay(&r, &model, &arch);
            let rpt = sta(nl, &packing, &arch, delay);
            cpds.push(rpt.cpd_ps / 1000.0);
            channel_util = r.channel_util.clone();
        } else {
            cpds.push(pl.est_cpd_ps / 1000.0);
        }
    }

    let cpd_ns = mean(&cpds);
    let alm_area_mwta = packing.stats.alms as f64 * arch.area.alm_mwta;
    FlowResult {
        name: name.to_string(),
        variant: arch.variant,
        luts: packing.stats.luts,
        adder_bits: packing.stats.adder_bits,
        alms: packing.stats.alms,
        lbs: packing.stats.lbs,
        concurrent_luts: packing.stats.concurrent_luts,
        alm_area_mwta,
        cpd_ns,
        adp: alm_area_mwta * cpd_ns,
        fmax_mhz: if cpd_ns > 0.0 { 1000.0 / cpd_ns } else { f64::INFINITY },
        routed_ok,
        route_iters: mean(&iters),
        channel_util,
        dedup_hits,
    }
}

/// Run a benchmark on one architecture variant.
pub fn run_benchmark(b: &Benchmark, variant: ArchVariant, opts: &FlowOpts) -> FlowResult {
    let circ = b.generate();
    let arch = Arch::coffe(variant);
    let mut r = run_flow(&circ, &arch, opts);
    r.name = b.name.clone();
    r
}

/// Pack-only fast path (Fig. 9 and quick stats).
pub fn pack_only(circ: &Circuit, variant: ArchVariant, unrelated: Unrelated) -> Packing {
    let nl = map_circuit(circ, &MapOpts::default());
    let arch = Arch::coffe(variant);
    pack(&nl, &arch, &PackOpts { unrelated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suites::{kratos_suite, BenchParams};

    #[test]
    fn full_flow_on_kratos_circuit() {
        let params = BenchParams::default();
        let b = &kratos_suite(&params)[2]; // gemmt
        let opts = FlowOpts { seeds: vec![1], place_effort: 0.2, ..Default::default() };
        let base = run_benchmark(b, ArchVariant::Baseline, &opts);
        assert!(base.alms > 0 && base.cpd_ns > 0.0 && base.adp > 0.0);
        assert!(base.routed_ok, "routing failed");
        let dd5 = run_benchmark(b, ArchVariant::Dd5, &opts);
        // The paper's core claim: DD5 uses no more ALMs on adder circuits.
        assert!(dd5.alms <= base.alms, "dd5 {} vs base {}", dd5.alms, base.alms);
    }

    #[test]
    fn multi_seed_averaging_runs() {
        let params = BenchParams::default();
        let b = &kratos_suite(&params)[0];
        let opts = FlowOpts {
            seeds: vec![1, 2],
            place_effort: 0.1,
            route: false,
            ..Default::default()
        };
        let r = run_benchmark(b, ArchVariant::Baseline, &opts);
        assert!(r.cpd_ns > 0.0);
    }
}
