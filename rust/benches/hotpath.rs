//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! placer move evaluation (incremental cost cache), router A* (serial vs
//! sharded PathFinder), packer, mapper, and the PJRT kernel evaluation
//! latency. No criterion offline — simple timed loops with enough
//! iterations for stable medians.
//!
//! `--quick` runs a CI-smoke subset: single iterations, the router
//! determinism check, no engine sweep.
use std::time::Instant;

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{kratos_suite, BenchParams};
use double_duty::coordinator::default_workers;
use double_duty::flow::engine::{Engine, ExperimentPlan};
use double_duty::flow::FlowOpts;
use double_duty::pack::{pack, PackOpts};
use double_duty::place::cost::{IncrementalCost, NetModel};
use double_duty::place::{place, PlaceOpts};
use double_duty::route::{route, RouteOpts, Routing};
use double_duty::techmap::{map_circuit, MapOpts};

fn timed<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    if per > 0.1 {
        println!("{name:<28} {:>10.1} ms/iter", per * 1e3);
    } else {
        println!("{name:<28} {:>10.1} us/iter", per * 1e6);
    }
}

fn routing_identical(a: &Routing, b: &Routing) -> bool {
    a.success == b.success
        && a.iterations == b.iterations
        && a.wirelength == b.wirelength
        && a.sink_hops == b.sink_hops
        && a.net_nodes == b.net_nodes
        && a.channel_util == b.channel_util
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let params = BenchParams::default();
    let suite = kratos_suite(&params);
    let bench = &suite[2]; // gemmt: the hotpath representative
    let circ = bench.generate();
    let arch = Arch::coffe(ArchVariant::Dd5);
    let reps = |full: usize| if quick { 1 } else { full };

    timed("synth+map gemmt", reps(5), || {
        let c = bench.generate();
        let _ = map_circuit(&c, &MapOpts::default());
    });

    let nl = map_circuit(&circ, &MapOpts::default());
    timed("pack gemmt", reps(10), || {
        let _ = pack(&nl, &arch, &PackOpts::default());
    });

    let packing = pack(&nl, &arch, &PackOpts::default());
    timed("place gemmt (effort 0.3)", reps(3), || {
        let _ = place(&nl, &packing, &arch,
                      &PlaceOpts { effort: 0.3, ..Default::default() });
    });

    let pl = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, ..Default::default() });
    let mut model = NetModel::build(&nl, &packing);
    model.set_weights(&[], false);

    timed("full_cost (rust)", reps(200), || {
        let _ = model.full_cost(&pl.lb_loc, &pl.io_loc);
    });
    let moved = [(0usize, double_duty::arch::device::Loc::new(2, 2))];
    timed("move_delta (scratch)", reps(20_000), || {
        let _ = model.move_delta(&pl.lb_loc, &pl.io_loc, &moved);
    });
    let inc = IncrementalCost::new(&model, &pl.lb_loc, &pl.io_loc);
    timed("move_delta (incremental)", reps(20_000), || {
        let _ = inc.move_delta(&model, &pl.lb_loc, &pl.io_loc, &moved);
    });

    match double_duty::place::kernel_accel::KernelCost::try_new(model.num_nets()) {
        Ok(mut k) => {
            timed("full_cost+congestion (PJRT)", reps(50), || {
                let _ = k.evaluate_cached(&model, &inc, &pl.device).unwrap();
            });
        }
        Err(e) => println!("PJRT kernel unavailable: {e}"),
    }

    timed("sta gemmt", reps(50), || {
        let _ = double_duty::timing::sta(&nl, &packing, &arch, |_, _, _| 150.0);
    });

    // --- Router: serial vs sharded PathFinder on the largest Kratos
    // circuit (by mapped cell count).  The ISSUE-2 acceptance bar is
    // >1.5x at 4 jobs; results must be bit-identical (the rrg
    // snapshot/reduce determinism contract).
    let (big_nl, big_name) = if quick {
        (nl.clone(), bench.name.clone())
    } else {
        suite
            .iter()
            .map(|b| (map_circuit(&b.generate(), &MapOpts::default()), b.name.clone()))
            .max_by_key(|(nl, _)| nl.cells.len())
            .expect("non-empty suite")
    };
    let big_pack = pack(&big_nl, &arch, &PackOpts::default());
    let big_pl = place(&big_nl, &big_pack, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() });
    let mut big_model = NetModel::build(&big_nl, &big_pack);
    big_model.set_weights(&[], false);

    let route_jobs = if quick { 2 } else { 4 };
    let route_reps = reps(3);
    let mut serial_route = None;
    let t0 = Instant::now();
    for _ in 0..route_reps {
        serial_route = Some(route(&big_model, &big_pl, &arch,
                                  &RouteOpts { jobs: 1, ..Default::default() }));
    }
    let t_serial = t0.elapsed().as_secs_f64() / route_reps as f64;
    let mut sharded_route = None;
    let t1 = Instant::now();
    for _ in 0..route_reps {
        sharded_route = Some(route(&big_model, &big_pl, &arch,
                                   &RouteOpts { jobs: route_jobs, ..Default::default() }));
    }
    let t_sharded = t1.elapsed().as_secs_f64() / route_reps as f64;
    let (sr, pr) = (serial_route.unwrap(), sharded_route.unwrap());
    assert!(routing_identical(&sr, &pr),
            "sharded router diverged from serial on {big_name}");
    println!("route {big_name:<18} jobs=1 {:>8.1} ms", t_serial * 1e3);
    println!(
        "route {big_name:<18} jobs={route_jobs} {:>7.1} ms  ({:.2}x speedup, {} iters, bit-identical)",
        t_sharded * 1e3,
        t_serial / t_sharded.max(1e-9),
        sr.iterations
    );

    if quick {
        println!("--quick: skipping engine sweep");
        return;
    }

    // Experiment-engine sweep: the paper-style grid (Kratos suite x
    // {baseline, DD5} x 3 seeds), serial vs parallel.  Both runs start
    // with a cold cache; results must match bit-for-bit (the engine's
    // determinism contract), so the wall-clock delta is pure scheduling.
    let sweep = ExperimentPlan {
        benches: kratos_suite(&params),
        variants: vec![ArchVariant::Baseline, ArchVariant::Dd5],
        flow: FlowOpts {
            seeds: vec![1, 2, 3],
            place_effort: 0.15,
            route: false,
            ..Default::default()
        },
    };
    let grid_cells = sweep.benches.len() * sweep.variants.len() * sweep.flow.seeds.len();
    // Warm the process-wide COFFE sizing cache for every swept variant so
    // neither timed run pays the one-time Arch::coffe cost.
    for &v in &sweep.variants {
        let _ = Arch::coffe(v);
    }
    let t0 = Instant::now();
    let serial = Engine::new(1).run(&sweep);
    let t_serial = t0.elapsed().as_secs_f64();

    let workers = default_workers();
    let engine = Engine::new(workers);
    let t1 = Instant::now();
    let parallel = engine.run(&sweep);
    let t_parallel = t1.elapsed().as_secs_f64();

    for (a, b) in serial.iter().flatten().zip(parallel.iter().flatten()) {
        assert!(
            a.alms == b.alms && a.cpd_ns == b.cpd_ns && a.adp == b.adp,
            "parallel engine diverged from serial on {}",
            a.name
        );
    }
    let st = &engine.cache.stats;
    use std::sync::atomic::Ordering::Relaxed;
    println!("engine sweep ({grid_cells} cells)  serial {t_serial:>8.2} s");
    println!(
        "engine sweep ({grid_cells} cells)  x{workers:<2} jobs {t_parallel:>6.2} s  ({:.2}x speedup)",
        t_serial / t_parallel.max(1e-9)
    );
    println!(
        "artifact cache: map {} misses / {} hits, pack {} misses / {} hits",
        st.map_misses.load(Relaxed),
        st.map_hits.load(Relaxed),
        st.pack_misses.load(Relaxed),
        st.pack_hits.load(Relaxed)
    );
}
