//! Closed-loop timing-driven routing contract, end to end (ISSUE-4):
//!
//! * (a) the closed loop (per-sink criticality weights + inter-iteration
//!   STA refresh) produces a bit-identical `Routing` *and* final
//!   `TimingReport` for any worker count — the PR-2 determinism contract
//!   extends through the timing feedback;
//! * (b) on a Kratos adder-chain circuit the closed loop's achieved
//!   critical-path delay stays within a 2% tie-breaking band of the
//!   timing-oblivious router (see the test doc for why not exact `<=`);
//! * (c) `sta_every = 0` reproduces the static-weight router (same
//!   `RouteOpts`, no feedback) exactly, bit for bit.

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{kratos_suite, BenchParams};
use double_duty::netlist::{Netlist, NetlistIndex, PackIndex};
use double_duty::pack::{pack, PackOpts, Packing};
use double_duty::place::cost::NetModel;
use double_duty::place::{net_endpoint_delay, place, PlaceOpts, Placement};
use double_duty::route::{route, route_timing, term_sink_crit, RouteOpts, Routing, TimingCtx};
use double_duty::techmap::{map_circuit, MapOpts};
use double_duty::timing::{sta_routed, sta_with, TimingReport};

struct Setup {
    nl: Netlist,
    packing: Packing,
    arch: Arch,
    pl: Placement,
    model: NetModel,
}

/// Map, pack and place a Kratos adder-chain circuit (gemms: constant-
/// weight GEMM, carry-chain dominated).  `channel_width = None` keeps the
/// paper default (lightly congested); a narrow width forces real
/// negotiation churn.
fn setup(channel_width: Option<u16>) -> Setup {
    let params = BenchParams::default();
    let b = &kratos_suite(&params)[3]; // gemms-FU-mini
    let circ = b.generate();
    let nl = map_circuit(&circ, &MapOpts::default());
    let mut arch = Arch::paper(ArchVariant::Dd5);
    if let Some(w) = channel_width {
        arch.routing.channel_width = w;
    }
    let packing = pack(&nl, &arch, &PackOpts::default());
    let pl = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.2, ..Default::default() })
        .expect("placement");
    let mut model = NetModel::build(&nl, &packing);
    model.set_weights(&[], false);
    Setup { nl, packing, arch, pl, model }
}

/// Pre-route per-sink criticalities, exactly as the flow seeds them:
/// STA over placed distance estimates, folded onto routing terminals.
fn preroute(s: &Setup) -> (NetlistIndex, PackIndex, Vec<Vec<f64>>) {
    let idx = NetlistIndex::build(&s.nl);
    let pidx = PackIndex::build(&s.nl, &s.packing);
    let rpt = sta_with(
        &s.nl,
        &idx,
        &pidx,
        &s.packing,
        &s.arch,
        |net, sink, _| net_endpoint_delay(&s.model, &s.pl.lb_loc, &s.pl.io_loc, &s.arch, net, sink),
        1,
    );
    let crit = term_sink_crit(&s.model, &idx, &rpt.sink_crit);
    (idx, pidx, crit)
}

fn assert_routing_eq(a: &Routing, b: &Routing, tag: &str) {
    assert_eq!(a.success, b.success, "{tag}: success");
    assert_eq!(a.iterations, b.iterations, "{tag}: iterations");
    assert_eq!(a.wirelength, b.wirelength, "{tag}: wirelength");
    assert_eq!(a.sink_hops, b.sink_hops, "{tag}: sink_hops");
    assert_eq!(a.net_nodes, b.net_nodes, "{tag}: net_nodes");
    assert_eq!(a.channel_util, b.channel_util, "{tag}: channel_util");
    assert_eq!(a.cpd_trace.len(), b.cpd_trace.len(), "{tag}: cpd_trace len");
    for (x, y) in a.cpd_trace.iter().zip(b.cpd_trace.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: cpd_trace");
    }
}

fn assert_report_eq(a: &TimingReport, b: &TimingReport, tag: &str) {
    assert!(a.bits_eq(b), "{tag}: TimingReport diverged (cpd {} vs {})", a.cpd_ps, b.cpd_ps);
}

/// (a) Bit-identity across worker counts, with the feedback loop actually
/// closing (narrow channel => multiple negotiation iterations => STA
/// refreshes between them).
#[test]
fn closed_loop_bit_identical_across_jobs() {
    let s = setup(Some(12));
    let (idx, pidx, sink_crit) = preroute(&s);
    let run = |jobs: usize| {
        let ropts = RouteOpts { jobs, sink_crit: sink_crit.clone(), ..Default::default() };
        let ctx = TimingCtx {
            nl: &s.nl,
            idx: &idx,
            pidx: &pidx,
            packing: &s.packing,
            sta_every: 2,
            crit_alpha: 0.5,
            sta_jobs: jobs,
        };
        let r = route_timing(&s.model, &s.pl, &s.arch, &ropts, &ctx);
        let rpt = sta_routed(&s.nl, &s.packing, &s.arch, &r, &s.model);
        (r, rpt)
    };
    let (base, base_rpt) = run(1);
    assert!(
        !base.cpd_trace.is_empty(),
        "feedback loop never closed (iterations {})",
        base.iterations
    );
    for jobs in [2usize, 8] {
        let (r, rpt) = run(jobs);
        assert_routing_eq(&base, &r, &format!("jobs={jobs}"));
        assert_report_eq(&base_rpt, &rpt, &format!("jobs={jobs}"));
    }
}

/// (b) Achieved CPD: closed loop must not be materially worse than the
/// timing-oblivious route (the paper's "no impact to critical path
/// delay" needs the router to *optimize* delay, not just measure it).
/// The contract this test pins is `closed <= oblivious * 1.02`: the run
/// is fully deterministic (no noise), but near-critical sinks can land
/// on equal-cost route choices whose hop counts differ by a segment, so
/// exact `<=` would over-constrain tie-breaking; 2% is far below any
/// real regression the loop could cause while still catching one.
#[test]
fn closed_loop_cpd_not_worse_than_oblivious() {
    let s = setup(None);
    let (idx, pidx, sink_crit) = preroute(&s);

    let plain = route(&s.model, &s.pl, &s.arch, &RouteOpts::default());
    assert!(plain.success, "oblivious route failed ({} overused)", plain.overused);
    let plain_cpd = sta_routed(&s.nl, &s.packing, &s.arch, &plain, &s.model).cpd_ps;

    let ropts = RouteOpts { sink_crit: sink_crit.clone(), ..Default::default() };
    let ctx = TimingCtx {
        nl: &s.nl,
        idx: &idx,
        pidx: &pidx,
        packing: &s.packing,
        sta_every: 1,
        crit_alpha: 0.5,
        sta_jobs: 1,
    };
    let closed = route_timing(&s.model, &s.pl, &s.arch, &ropts, &ctx);
    assert!(closed.success, "closed-loop route failed ({} overused)", closed.overused);
    let closed_cpd = sta_routed(&s.nl, &s.packing, &s.arch, &closed, &s.model).cpd_ps;

    assert!(
        closed_cpd <= plain_cpd * 1.02 + 1e-9,
        "closed-loop CPD {closed_cpd} ps vs oblivious {plain_cpd} ps"
    );
}

/// (c) `sta_every = 0` is the static-weight router, exactly: same
/// `RouteOpts`, feedback disabled => bit-identical routing.
#[test]
fn sta_every_zero_is_static_weights_exactly() {
    let s = setup(Some(14));
    let (idx, pidx, sink_crit) = preroute(&s);

    let ropts = RouteOpts { sink_crit: sink_crit.clone(), ..Default::default() };
    let static_route = route(&s.model, &s.pl, &s.arch, &ropts);
    let ctx = TimingCtx {
        nl: &s.nl,
        idx: &idx,
        pidx: &pidx,
        packing: &s.packing,
        sta_every: 0,
        crit_alpha: 0.5,
        sta_jobs: 1,
    };
    let no_feedback = route_timing(&s.model, &s.pl, &s.arch, &ropts, &ctx);
    assert!(no_feedback.cpd_trace.is_empty(), "sta_every=0 must never refresh");
    assert_routing_eq(&static_route, &no_feedback, "sta_every=0 vs static");
}

/// Flow-level plumbing: `--timing-route` records the CPD trajectory, its
/// final entry is the reported CPD, and `route_jobs` never perturbs it.
#[test]
fn flow_records_cpd_trajectory_deterministically() {
    use double_duty::flow::{place_route_seed, FlowOpts, SeedCtx};
    let s = setup(None);
    let idx = NetlistIndex::build(&s.nl);
    let pidx = PackIndex::build(&s.nl, &s.packing);
    let mk = |route_jobs: usize| {
        let opts = FlowOpts {
            seeds: vec![1],
            place_effort: 0.2,
            route_jobs,
            route_timing_weights: true,
            sta_every: 2,
            crit_alpha: 0.5,
            ..Default::default()
        };
        place_route_seed(&s.nl, &s.packing, &s.arch, &opts, 1, &SeedCtx::new(&idx, &pidx))
    };
    let serial = mk(1);
    assert!(!serial.cpd_trace_ns.is_empty());
    let last = *serial.cpd_trace_ns.last().unwrap();
    assert_eq!(last.to_bits(), serial.cpd_ns.to_bits(), "trace ends at the reported CPD");
    let parallel = mk(4);
    assert_eq!(serial.cpd_trace_ns.len(), parallel.cpd_trace_ns.len());
    for (a, b) in serial.cpd_trace_ns.iter().zip(parallel.cpd_trace_ns.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "route_jobs perturbed the trajectory");
    }
    assert_eq!(serial.cpd_ns.to_bits(), parallel.cpd_ns.to_bits());
}
