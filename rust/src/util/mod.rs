//! Small shared utilities: deterministic PRNG, statistics, table printing.
//!
//! The offline environment has no `rand`/`criterion`/`prettytable`; these
//! replacements are tiny, deterministic, and dependency-free.

pub mod error;
pub mod fault;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use stats::{geomean, mean};
pub use table::Table;
