//! And-Inverter Graph with structural hashing and constant folding.
//!
//! The pre-mapping logic representation (the ABC substitute's core).  All
//! combinational logic — including the compressor-tree carry-save gates the
//! arithmetic synthesis emits — lives here; hard carry-chain adders stay
//! outside as macros whose operand inputs are [`Lit`]s into this graph and
//! whose sum/cout outputs re-enter it as [`LeafKind`] leaf nodes.

use std::collections::HashMap;

/// Node index.
pub type NodeId = u32;

/// A literal: node id with a complement bit in the LSB.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Lit(pub u32);

impl Lit {
    pub const FALSE: Lit = Lit(0);
    pub const TRUE: Lit = Lit(1);

    #[inline]
    pub fn new(node: NodeId, compl: bool) -> Lit {
        Lit(node << 1 | compl as u32)
    }

    #[inline]
    pub fn node(self) -> NodeId {
        self.0 >> 1
    }

    #[inline]
    pub fn is_compl(self) -> bool {
        self.0 & 1 == 1
    }

    #[inline]
    #[must_use]
    pub fn compl(self) -> Lit {
        Lit(self.0 ^ 1)
    }

    #[inline]
    pub fn is_const(self) -> bool {
        self.node() == 0
    }
}

impl std::fmt::Debug for Lit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_compl() {
            write!(f, "!n{}", self.node())
        } else {
            write!(f, "n{}", self.node())
        }
    }
}

/// External leaf sources feeding the AIG.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LeafKind {
    /// Primary input (index into the circuit's PI list).
    Pi(u32),
    /// Flip-flop output (index into the circuit's FF list).
    FfQ(u32),
    /// Sum output of carry-chain `chain`, bit `pos`.
    AdderSum { chain: u32, pos: u32 },
    /// Final carry-out of carry-chain `chain`.
    AdderCout { chain: u32 },
}

/// AIG node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Node {
    /// Node 0 only: constant false.
    Const0,
    /// External source (PI, FF output, adder output).
    Leaf(LeafKind),
    /// Two-input AND of literals.
    And(Lit, Lit),
}

/// Result of [`Aig::levelize`]: per-node depth plus the nodes grouped
/// level-by-level (ids ascending within a level).
#[derive(Clone, Debug)]
pub struct AigLevels {
    /// Per node: its level (0 = constants and leaves).
    pub level_of: Vec<u32>,
    /// CSR wave offsets into `order`; length `num_levels + 1`.
    pub offsets: Vec<usize>,
    /// Nodes grouped by level, ids ascending within each level.
    pub order: Vec<NodeId>,
}

impl AigLevels {
    pub fn num_levels(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Nodes of level `l`, ids ascending.
    pub fn level_nodes(&self, l: usize) -> &[NodeId] {
        &self.order[self.offsets[l]..self.offsets[l + 1]]
    }
}

/// The graph.
#[derive(Clone, Debug, Default)]
pub struct Aig {
    pub nodes: Vec<Node>,
    strash: HashMap<(Lit, Lit), NodeId>,
    /// Reference (fanout) counts, maintained for mapped-area heuristics.
    pub n_pis: u32,
}

impl Aig {
    pub fn new() -> Self {
        Aig { nodes: vec![Node::Const0], strash: HashMap::new(), n_pis: 0 }
    }

    /// Add a primary input leaf; returns its (positive) literal.
    pub fn pi(&mut self) -> Lit {
        let idx = self.n_pis;
        self.n_pis += 1;
        self.leaf(LeafKind::Pi(idx))
    }

    /// Add an arbitrary leaf node.
    pub fn leaf(&mut self, kind: LeafKind) -> Lit {
        let id = self.nodes.len() as NodeId;
        self.nodes.push(Node::Leaf(kind));
        Lit::new(id, false)
    }

    /// Structural-hashed AND with constant folding and trivial rules.
    pub fn and(&mut self, a: Lit, b: Lit) -> Lit {
        // Constant / trivial cases.
        if a == Lit::FALSE || b == Lit::FALSE {
            return Lit::FALSE;
        }
        if a == Lit::TRUE {
            return b;
        }
        if b == Lit::TRUE {
            return a;
        }
        if a == b {
            return a;
        }
        if a == b.compl() {
            return Lit::FALSE;
        }
        // Canonical order for hashing.
        let (a, b) = if a.0 <= b.0 { (a, b) } else { (b, a) };
        if let Some(&id) = self.strash.get(&(a, b)) {
            return Lit::new(id, false);
        }
        let id = self.nodes.len() as NodeId;
        self.nodes.push(Node::And(a, b));
        self.strash.insert((a, b), id);
        Lit::new(id, false)
    }

    pub fn or(&mut self, a: Lit, b: Lit) -> Lit {
        self.and(a.compl(), b.compl()).compl()
    }

    pub fn xor(&mut self, a: Lit, b: Lit) -> Lit {
        let n1 = self.and(a, b.compl());
        let n2 = self.and(a.compl(), b);
        self.or(n1, n2)
    }

    pub fn xor3(&mut self, a: Lit, b: Lit, c: Lit) -> Lit {
        let ab = self.xor(a, b);
        self.xor(ab, c)
    }

    /// Majority-of-three (full-adder carry).
    pub fn maj3(&mut self, a: Lit, b: Lit, c: Lit) -> Lit {
        let ab = self.and(a, b);
        let ac = self.and(a, c);
        let bc = self.and(b, c);
        let t = self.or(ab, ac);
        self.or(t, bc)
    }

    /// 2:1 mux: `s ? t : e`.
    pub fn mux(&mut self, s: Lit, t: Lit, e: Lit) -> Lit {
        let st = self.and(s, t);
        let se = self.and(s.compl(), e);
        self.or(st, se)
    }

    /// Build the function of a K-input truth table (K ≤ 6, LSB-first row
    /// indexing exactly as [`crate::netlist::CellKind::Lut`] stores it:
    /// `ins[0]` is bit 0 of the row index) over the literals `ins` by
    /// recursive Shannon cofactoring on the highest variable.  Constant
    /// cofactors fold immediately and structural hashing dedups shared
    /// subfunctions, so simple masks (AND/OR/inverter rows) reduce to the
    /// canonical AIG shapes.  The inverse of the mapper's `cone_truth`;
    /// `check::equiv` uses it to lift mapped LUT masks back into AIG form.
    pub fn from_truth(&mut self, truth: u64, ins: &[Lit]) -> Lit {
        let k = ins.len().min(6);
        let rows = 1usize << k;
        let mask = if rows >= 64 { u64::MAX } else { (1u64 << rows) - 1 };
        let t = truth & mask;
        if t == 0 {
            return Lit::FALSE;
        }
        if t == mask {
            return Lit::TRUE;
        }
        // k >= 1 here (a 0-input table is constant and returned above).
        let h = k - 1;
        let half_rows = 1usize << h;
        let half_mask = if half_rows >= 64 { u64::MAX } else { (1u64 << half_rows) - 1 };
        let t0 = t & half_mask; // ins[h] = 0 cofactor
        let t1 = (t >> half_rows) & half_mask; // ins[h] = 1 cofactor
        let f0 = self.from_truth(t0, &ins[..h]);
        let f1 = self.from_truth(t1, &ins[..h]);
        self.mux(ins[h], f1, f0)
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.len() <= 1
    }

    /// Count AND nodes (logic size).
    pub fn num_ands(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::And(..))).count()
    }

    /// Evaluate a literal under a leaf assignment (for tests/oracles).
    /// `leaf_val(kind)` supplies values for leaves.
    pub fn eval<F: Fn(LeafKind) -> bool + Copy>(&self, lit: Lit, leaf_val: F) -> bool {
        // Iterative post-order evaluation with memoization.
        let mut memo: HashMap<NodeId, bool> = HashMap::new();
        let mut stack = vec![lit.node()];
        while let Some(&id) = stack.last() {
            if memo.contains_key(&id) {
                stack.pop();
                continue;
            }
            match self.nodes[id as usize] {
                Node::Const0 => {
                    memo.insert(id, false);
                    stack.pop();
                }
                Node::Leaf(k) => {
                    memo.insert(id, leaf_val(k));
                    stack.pop();
                }
                Node::And(a, b) => {
                    let need_a = !memo.contains_key(&a.node());
                    let need_b = !memo.contains_key(&b.node());
                    if need_a {
                        stack.push(a.node());
                    }
                    if need_b {
                        stack.push(b.node());
                    }
                    if !need_a && !need_b {
                        let va = memo[&a.node()] ^ a.is_compl();
                        let vb = memo[&b.node()] ^ b.is_compl();
                        memo.insert(id, va && vb);
                        stack.pop();
                    }
                }
            }
        }
        memo[&lit.node()] ^ lit.is_compl()
    }

    /// Topological levelization: level 0 holds `Const0` and every leaf;
    /// an AND node sits one past its deepest fanin.  Nodes within one
    /// level never reference each other, so the level groups are the wave
    /// schedule the parallel cut enumeration runs on
    /// ([`crate::coordinator::parallel_waves_with`]).  Node ids are
    /// already topological (a node only references smaller ids), so this
    /// is a single O(n) sweep plus a counting sort — fully deterministic.
    pub fn levelize(&self) -> AigLevels {
        let n = self.nodes.len();
        let mut level_of = vec![0u32; n];
        for id in 0..n {
            if let Node::And(a, b) = self.nodes[id] {
                level_of[id] =
                    1 + level_of[a.node() as usize].max(level_of[b.node() as usize]);
            }
        }
        let num_levels = level_of.iter().copied().max().map_or(0, |m| m as usize + 1);
        let mut offsets = vec![0usize; num_levels + 1];
        for &l in &level_of {
            offsets[l as usize + 1] += 1;
        }
        for l in 0..num_levels {
            offsets[l + 1] += offsets[l];
        }
        let mut cursor = offsets.clone();
        let mut order = vec![0 as NodeId; n];
        for id in 0..n {
            let l = level_of[id] as usize;
            order[cursor[l]] = id as NodeId;
            cursor[l] += 1;
        }
        AigLevels { level_of, offsets, order }
    }

    /// Fanout counts of every node reachable from `roots` (and the roots'
    /// own references), used by area-flow heuristics and absorption rules.
    pub fn fanout_counts(&self, roots: &[Lit]) -> Vec<u32> {
        let mut counts = vec![0u32; self.nodes.len()];
        for r in roots {
            counts[r.node() as usize] += 1;
        }
        // Count structural references from AND nodes (the whole graph).
        for n in &self.nodes {
            if let Node::And(a, b) = n {
                counts[a.node() as usize] += 1;
                counts[b.node() as usize] += 1;
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_folding() {
        let mut g = Aig::new();
        let a = g.pi();
        assert_eq!(g.and(a, Lit::FALSE), Lit::FALSE);
        assert_eq!(g.and(a, Lit::TRUE), a);
        assert_eq!(g.and(a, a), a);
        assert_eq!(g.and(a, a.compl()), Lit::FALSE);
    }

    #[test]
    fn structural_hashing_dedups() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let x = g.and(a, b);
        let y = g.and(b, a);
        assert_eq!(x, y);
        assert_eq!(g.num_ands(), 1);
    }

    #[test]
    fn xor_truth() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let x = g.xor(a, b);
        for (va, vb) in [(false, false), (false, true), (true, false), (true, true)] {
            let got = g.eval(x, |k| match k {
                LeafKind::Pi(0) => va,
                LeafKind::Pi(1) => vb,
                _ => unreachable!(),
            });
            assert_eq!(got, va ^ vb);
        }
    }

    #[test]
    fn maj_and_mux_truth() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let c = g.pi();
        let m = g.maj3(a, b, c);
        let x = g.mux(a, b, c);
        for i in 0..8u32 {
            let v = [i & 1 == 1, i >> 1 & 1 == 1, i >> 2 & 1 == 1];
            let leaf = |k: LeafKind| match k {
                LeafKind::Pi(j) => v[j as usize],
                _ => unreachable!(),
            };
            assert_eq!(g.eval(m, leaf),
                       (v[0] & v[1]) | (v[0] & v[2]) | (v[1] & v[2]));
            assert_eq!(g.eval(x, leaf), if v[0] { v[1] } else { v[2] });
        }
    }

    #[test]
    fn xor3_is_parity() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let c = g.pi();
        let s = g.xor3(a, b, c);
        for i in 0..8u32 {
            let v = [i & 1 == 1, i >> 1 & 1 == 1, i >> 2 & 1 == 1];
            let got = g.eval(s, |k| match k {
                LeafKind::Pi(j) => v[j as usize],
                _ => unreachable!(),
            });
            assert_eq!(got, v[0] ^ v[1] ^ v[2]);
        }
    }

    #[test]
    fn from_truth_matches_table() {
        for k in 0..=4usize {
            let rows = 1usize << k;
            let mask: u64 = if rows >= 64 { u64::MAX } else { (1u64 << rows) - 1 };
            // A handful of masks incl. the corners, exhaustively checked.
            for seed in [0u64, mask, 0xA5A5_A5A5_A5A5_A5A5 & mask, 0x6 & mask, 0x17 & mask] {
                let mut g = Aig::new();
                let ins: Vec<Lit> = (0..k).map(|_| g.pi()).collect();
                let f = g.from_truth(seed, &ins);
                for row in 0..rows {
                    let got = g.eval(f, |kind| match kind {
                        LeafKind::Pi(i) => row >> i & 1 == 1,
                        _ => unreachable!(),
                    });
                    assert_eq!(got, seed >> row & 1 == 1, "k={k} truth={seed:#x} row={row}");
                }
            }
        }
    }

    #[test]
    fn from_truth_folds_simple_masks() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        // AND mask folds to the canonical strash node; inverter folds to
        // a complement literal with no new nodes.
        let f_and = g.from_truth(0b1000, &[a, b]);
        assert_eq!(f_and, g.and(a, b));
        let before = g.len();
        let f_inv = g.from_truth(0b01, &[a]);
        assert_eq!(f_inv, a.compl());
        assert_eq!(g.len(), before);
    }

    #[test]
    fn levelize_groups_by_depth() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let x = g.xor(a, b); // two level-1 ANDs under one level-2 AND
        let y = g.and(x, a);
        let lv = g.levelize();
        assert_eq!(lv.level_of[0], 0); // Const0
        assert_eq!(lv.level_of[a.node() as usize], 0);
        assert_eq!(lv.level_of[b.node() as usize], 0);
        assert_eq!(lv.level_of[x.node() as usize], 2);
        assert_eq!(lv.level_of[y.node() as usize], 3);
        assert_eq!(lv.num_levels(), 4);
        // Order covers every node once, grouped by level, ascending ids.
        assert_eq!(lv.order.len(), g.len());
        let mut seen = vec![false; g.len()];
        for l in 0..lv.num_levels() {
            let nodes = lv.level_nodes(l);
            for w in nodes.windows(2) {
                assert!(w[0] < w[1]);
            }
            for &id in nodes {
                assert_eq!(lv.level_of[id as usize] as usize, l);
                assert!(!seen[id as usize]);
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // Every AND sits strictly above both fanins.
        for (id, n) in g.nodes.iter().enumerate() {
            if let Node::And(a, b) = n {
                assert!(lv.level_of[id] > lv.level_of[a.node() as usize]);
                assert!(lv.level_of[id] > lv.level_of[b.node() as usize]);
            }
        }
    }

    #[test]
    fn fanout_counts() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let x = g.and(a, b);
        let y = g.and(x, b.compl());
        let counts = g.fanout_counts(&[y]);
        assert_eq!(counts[x.node() as usize], 1);
        assert_eq!(counts[b.node() as usize], 2);
    }
}
