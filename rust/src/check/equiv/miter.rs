//! Miter construction: source AIG vs mapped/packed netlist, one shared
//! AIG, one XOR output per comparison point.
//!
//! Both sides are rebuilt into a *single* fresh [`Aig`] whose only leaves
//! are the sequential-cut inputs (circuit PIs, then FF q outputs).  The
//! spec side replays the source circuit: AND nodes map one-to-one and
//! every hard carry chain is expanded into ripple `xor3`/`maj3` logic on
//! first use.  The impl side evaluates the netlist in combinational
//! topological order: `Lut` masks lift back into AIG form via
//! [`Aig::from_truth`], `AdderBit` cells become the same `xor3`/`maj3`
//! forms, and — in the packed view — adder operands are resolved through
//! the packing's [`OperandPath`]s, so a wrong absorption decision changes
//! the modelled function and the miter catches it.
//!
//! Because both sides share one structurally-hashed graph, most of a
//! healthy netlist *folds*: a LUT whose mask provably equals its spec
//! cone (checked by exhaustive cofactor evaluation over the ≤ 6 cut
//! leaves — a local proof, never an assumption) is merged onto the spec
//! literal, carries then ripple onto identical nodes, and the XOR at
//! each output collapses to constant false.  Cones that do not fold go
//! to simulation and SAT in [`super`].  The mapper's `lut_n<id>` cell
//! names are used only as merge *hints*; a lying name fails the local
//! proof and the cone simply stays unmerged — soundness never rests on
//! naming.

use super::{Severity, Stage, Violation};
use crate::netlist::{CellKind, Netlist, NetlistIndex};
use crate::pack::{OperandPath, Packing};
use crate::synth::circuit::{AdderChainMacro, Circuit};
use crate::techmap::aig::{Aig, LeafKind, Lit, Node};

/// Which netlist view the impl side models.
pub enum EquivView<'a> {
    /// The mapped netlist as-is (post-`techmap`).
    Mapped,
    /// Adder operands re-resolved through the packing's operand paths
    /// (post-`pack`; packing must be logic-neutral).
    Packed(&'a Packing),
}

/// One comparison point (PO or FF data input).
pub struct MiterOutput {
    /// `po <name>` or `ff<i>.d` — the stable scan label.
    pub name: String,
    pub spec: Lit,
    pub impl_lit: Lit,
    /// `spec XOR impl`; `Lit::FALSE` means proven equivalent by folding.
    pub miter: Lit,
}

/// The assembled miter.
pub struct Miter {
    pub aig: Aig,
    /// Input names: circuit PIs in declaration order, then `ff<i>.q`.
    pub inputs: Vec<String>,
    /// How many of `inputs` are PIs (the rest are FF state bits).
    pub n_pis: usize,
    /// Comparison points in stable scan order: POs, then FF d pins.
    pub outputs: Vec<MiterOutput>,
    /// LUT cells merged onto their spec cone via a local cut-point proof.
    pub merged_luts: usize,
    /// LUT cells lifted via `from_truth` (left for simulation/SAT).
    pub unmerged_luts: usize,
}

fn shape(location: impl Into<String>, message: impl Into<String>) -> Violation {
    Violation::new(Stage::Equiv, Severity::Error, "equiv.shape", location, message)
}

#[inline]
fn spec_of(spec: &[Lit], l: Lit) -> Lit {
    let base = spec.get(l.node() as usize).copied().unwrap_or(Lit::FALSE);
    if l.is_compl() {
        base.compl()
    } else {
        base
    }
}

/// Ripple-expand one hard chain into the miter AIG.
fn expand_chain(aig: &mut Aig, ch: &AdderChainMacro, spec: &[Lit]) -> (Vec<Lit>, Lit) {
    let mut carry = spec_of(spec, ch.cin);
    let mut sums = Vec::with_capacity(ch.ops.len());
    for &(a, b) in &ch.ops {
        let ma = spec_of(spec, a);
        let mb = spec_of(spec, b);
        sums.push(aig.xor3(ma, mb, carry));
        carry = aig.maj3(ma, mb, carry);
    }
    (sums, carry)
}

/// Parse a mapper LUT cell name into its spec-AIG root hint:
/// `lut_n<id>` / `lut_n<id>_neg` / `inv_n<id>` → (node id, complemented).
fn parse_lut_root(name: &str) -> Option<(u32, bool)> {
    if let Some(rest) = name.strip_prefix("lut_n") {
        let (digits, neg) = match rest.strip_suffix("_neg") {
            Some(d) => (d, true),
            None => (rest, false),
        };
        return digits.parse::<u32>().ok().map(|n| (n, neg));
    }
    if let Some(digits) = name.strip_prefix("inv_n") {
        return digits.parse::<u32>().ok().map(|n| (n, true));
    }
    None
}

/// Local cut-point proof: is `cand` (a miter literal) equal to
/// `truth` over `ins` for *every* valuation of the boundary nodes?
///
/// The cone of `cand` is walked down to the nodes of `ins`; if it stays
/// inside that boundary (and small), the claim is checked exhaustively
/// over the ≤ 2^6 boundary valuations.  Proving equality over all
/// boundary valuations is stronger than equality over the reachable ones,
/// so a `true` answer makes merging `cand` for the LUT output *sound*;
/// `false` only means "could not prove locally" and the caller falls back
/// to the global machinery.
fn local_prove(aig: &Aig, cand: Lit, truth: u64, ins: &[Lit]) -> bool {
    const CONE_CAP: usize = 512;
    let mut boundary: Vec<u32> = ins.iter().map(|l| l.node()).filter(|&n| n != 0).collect();
    boundary.sort_unstable();
    boundary.dedup();
    if boundary.len() > 6 {
        return false;
    }
    // Cone of cand bounded by the boundary nodes.
    let mut cone: Vec<u32> = Vec::new();
    let mut stack = vec![cand.node()];
    while let Some(id) = stack.pop() {
        if id == 0 || boundary.binary_search(&id).is_ok() || cone.contains(&id) {
            continue;
        }
        match *aig.node(id) {
            Node::And(a, b) => {
                cone.push(id);
                if cone.len() > CONE_CAP {
                    return false;
                }
                stack.push(a.node());
                stack.push(b.node());
            }
            // A leaf outside the boundary: the candidate depends on
            // something the LUT cannot see — unprovable locally.
            _ => return false,
        }
    }
    cone.sort_unstable();

    let mut cone_vals = vec![false; cone.len()];
    for m in 0u32..(1u32 << boundary.len()) {
        let node_val = |id: u32, cone_vals: &[bool]| -> Option<bool> {
            if id == 0 {
                return Some(false);
            }
            if let Ok(i) = boundary.binary_search(&id) {
                return Some(m >> i & 1 == 1);
            }
            cone.binary_search(&id).ok().and_then(|i| cone_vals.get(i).copied())
        };
        // Ascending node id is topological: fanins resolve first.
        for ci in 0..cone.len() {
            let Node::And(a, b) = *aig.node(cone[ci]) else { return false };
            let (Some(va), Some(vb)) =
                (node_val(a.node(), &cone_vals), node_val(b.node(), &cone_vals))
            else {
                return false;
            };
            cone_vals[ci] = (va ^ a.is_compl()) && (vb ^ b.is_compl());
        }
        let Some(cv) = node_val(cand.node(), &cone_vals) else { return false };
        let cand_v = cv ^ cand.is_compl();
        let mut row = 0usize;
        for (i, l) in ins.iter().enumerate() {
            let Some(v) = node_val(l.node(), &cone_vals) else { return false };
            row |= ((v ^ l.is_compl()) as usize) << i;
        }
        if cand_v != (truth >> row & 1 == 1) {
            return false;
        }
    }
    true
}

/// Resolve one packed adder operand through its [`OperandPath`].
fn resolve_operand(
    path: OperandPath,
    net_val: Lit,
    nl: &Netlist,
    net_lit: &[Lit],
) -> Lit {
    match path {
        // Const / route-through / Z-bypass all deliver the net's value
        // unchanged (tie-off, LUT pass-through, dedicated bypass pin).
        OperandPath::Const | OperandPath::RouteThrough | OperandPath::ZBypass => net_val,
        // An absorbed feeder hardwires *that LUT's* function into the
        // operand — model exactly that, so absorbing the wrong LUT is a
        // functional difference the miter sees.
        OperandPath::AbsorbedLut(l) => nl
            .cells
            .get(l as usize)
            .and_then(|c| c.outs.first())
            .and_then(|&n| net_lit.get(n as usize))
            .copied()
            .unwrap_or(net_val),
    }
}

/// Build the miter between `circ` and `nl` under `view`.
pub fn build(
    circ: &Circuit,
    nl: &Netlist,
    idx: &NetlistIndex,
    view: &EquivView<'_>,
) -> Result<Miter, Violation> {
    let n_pis = circ.pis.len();
    let n_ffs = circ.ffs.len();

    let mut aig = Aig::new();
    let mut in_lits = Vec::with_capacity(n_pis + n_ffs);
    let mut inputs = Vec::with_capacity(n_pis + n_ffs);
    for name in &circ.pis {
        in_lits.push(aig.pi());
        inputs.push(name.clone());
    }
    for i in 0..n_ffs {
        in_lits.push(aig.pi());
        inputs.push(format!("ff{i}.q"));
    }

    // --- Spec side: replay the source AIG (ids are topological). --------
    let mut spec = vec![Lit::FALSE; circ.aig.len()];
    let mut chain_sums: Vec<Option<(Vec<Lit>, Lit)>> = vec![None; circ.chains.len()];
    for id in 1..circ.aig.len() as u32 {
        let lit = match *circ.aig.node(id) {
            Node::Const0 => Lit::FALSE,
            Node::And(a, b) => {
                let ma = spec_of(&spec, a);
                let mb = spec_of(&spec, b);
                aig.and(ma, mb)
            }
            Node::Leaf(LeafKind::Pi(i)) => match in_lits.get(i as usize) {
                Some(&l) if (i as usize) < n_pis => l,
                _ => return Err(shape(format!("aig node {id}"), "PI leaf out of range")),
            },
            Node::Leaf(LeafKind::FfQ(i)) => match in_lits.get(n_pis + i as usize) {
                Some(&l) => l,
                None => return Err(shape(format!("aig node {id}"), "FF leaf out of range")),
            },
            Node::Leaf(LeafKind::AdderSum { chain, pos }) => {
                let ci = chain as usize;
                let Some(ch) = circ.chains.get(ci) else {
                    return Err(shape(format!("aig node {id}"), "chain leaf out of range"));
                };
                if chain_sums[ci].is_none() {
                    chain_sums[ci] = Some(expand_chain(&mut aig, ch, &spec));
                }
                match chain_sums[ci].as_ref().and_then(|(s, _)| s.get(pos as usize)) {
                    Some(&l) => l,
                    None => {
                        return Err(shape(
                            format!("chain {chain}"),
                            format!("sum position {pos} out of range"),
                        ))
                    }
                }
            }
            Node::Leaf(LeafKind::AdderCout { chain }) => {
                let ci = chain as usize;
                let Some(ch) = circ.chains.get(ci) else {
                    return Err(shape(format!("aig node {id}"), "chain leaf out of range"));
                };
                if chain_sums[ci].is_none() {
                    chain_sums[ci] = Some(expand_chain(&mut aig, ch, &spec));
                }
                match chain_sums[ci].as_ref() {
                    Some(&(_, cout)) => cout,
                    None => return Err(shape(format!("chain {chain}"), "cout unavailable")),
                }
            }
        };
        spec[id as usize] = lit;
    }

    // --- Impl side: evaluate the netlist over per-net literals. ----------
    if nl.inputs.len() != n_pis {
        return Err(shape(
            "inputs",
            format!("netlist has {} inputs, circuit has {n_pis} PIs", nl.inputs.len()),
        ));
    }
    let mut net_lit = vec![Lit::FALSE; nl.nets.len()];
    for (i, &cid) in nl.inputs.iter().enumerate() {
        let Some(&net) = nl.cells.get(cid as usize).and_then(|c| c.outs.first()) else {
            return Err(shape(format!("cell {cid}"), "input cell without output net"));
        };
        net_lit[net as usize] = in_lits[i];
    }
    let ff_cells: Vec<u32> = nl
        .cells
        .iter()
        .enumerate()
        .filter(|(_, c)| matches!(c.kind, CellKind::Ff))
        .map(|(i, _)| i as u32)
        .collect();
    if ff_cells.len() != n_ffs {
        return Err(shape(
            "ffs",
            format!("netlist has {} FFs, circuit has {n_ffs}", ff_cells.len()),
        ));
    }
    for (i, &cid) in ff_cells.iter().enumerate() {
        let Some(&net) = nl.cells.get(cid as usize).and_then(|c| c.outs.first()) else {
            return Err(shape(format!("cell {cid}"), "FF cell without q net"));
        };
        net_lit[net as usize] = in_lits[n_pis + i];
    }
    for (cid, cell) in nl.cells.iter().enumerate() {
        if let CellKind::Const(v) = cell.kind {
            let Some(&net) = cell.outs.first() else {
                return Err(shape(format!("cell {cid}"), "const cell without output net"));
            };
            net_lit[net as usize] = if v { Lit::TRUE } else { Lit::FALSE };
        }
    }

    // Packed view: operand paths per adder-bit cell.
    let mut paths: Vec<Option<[OperandPath; 2]>> = Vec::new();
    if let EquivView::Packed(packing) = view {
        paths = vec![None; nl.cells.len()];
        for alm in &packing.alms {
            for (bi, &c) in alm.adder_bits.iter().enumerate() {
                if let (Some(slot), Some(&p)) =
                    (paths.get_mut(c as usize), alm.operand_paths.get(bi))
                {
                    *slot = Some(p);
                }
            }
        }
    }

    let mut merged_luts = 0usize;
    let mut unmerged_luts = 0usize;
    for &cid in idx.topo_order() {
        let Some(cell) = nl.cells.get(cid as usize) else { continue };
        match cell.kind {
            CellKind::Lut { truth, .. } => {
                let ins: Vec<Lit> = cell
                    .ins
                    .iter()
                    .map(|&n| net_lit.get(n as usize).copied().unwrap_or(Lit::FALSE))
                    .collect();
                let Some(&out) = cell.outs.first() else { continue };
                let cand = parse_lut_root(&cell.name).and_then(|(node, neg)| {
                    spec.get(node as usize).map(|&l| if neg { l.compl() } else { l })
                });
                let lit = match cand {
                    Some(c) if local_prove(&aig, c, truth, &ins) => {
                        merged_luts += 1;
                        c
                    }
                    _ => {
                        unmerged_luts += 1;
                        aig.from_truth(truth, &ins)
                    }
                };
                if let Some(slot) = net_lit.get_mut(out as usize) {
                    *slot = lit;
                }
            }
            CellKind::AdderBit { .. } => {
                let get_in = |pin: usize| -> Lit {
                    cell.ins
                        .get(pin)
                        .and_then(|&n| net_lit.get(n as usize))
                        .copied()
                        .unwrap_or(Lit::FALSE)
                };
                let mut a = get_in(0);
                let mut b = get_in(1);
                let c = get_in(2);
                if let Some(Some([pa, pb])) = paths.get(cid as usize) {
                    a = resolve_operand(*pa, a, nl, &net_lit);
                    b = resolve_operand(*pb, b, nl, &net_lit);
                }
                let sum = aig.xor3(a, b, c);
                let cout = aig.maj3(a, b, c);
                if let Some(&sn) = cell.outs.first() {
                    if let Some(slot) = net_lit.get_mut(sn as usize) {
                        *slot = sum;
                    }
                }
                if let Some(&cn) = cell.outs.get(1) {
                    if let Some(slot) = net_lit.get_mut(cn as usize) {
                        *slot = cout;
                    }
                }
            }
            _ => {}
        }
    }

    // --- Comparison points: POs in order, then FF d pins. ----------------
    if nl.outputs.len() != circ.pos.len() {
        return Err(shape(
            "outputs",
            format!("netlist has {} outputs, circuit has {} POs", nl.outputs.len(), circ.pos.len()),
        ));
    }
    let mut outputs = Vec::with_capacity(circ.pos.len() + n_ffs);
    for (i, (name, slit)) in circ.pos.iter().enumerate() {
        let ocell = nl.outputs[i];
        let Some(ocell_ref) = nl.cells.get(ocell as usize) else {
            return Err(shape(format!("po {name}"), "output cell missing"));
        };
        if ocell_ref.name != *name {
            return Err(shape(
                format!("po {name}"),
                format!("netlist output {i} is named '{}'", ocell_ref.name),
            ));
        }
        let Some(&inet) = ocell_ref.ins.first() else {
            return Err(shape(format!("po {name}"), "output cell without input net"));
        };
        let spec_l = spec_of(&spec, *slit);
        let impl_l = net_lit.get(inet as usize).copied().unwrap_or(Lit::FALSE);
        let miter = aig.xor(spec_l, impl_l);
        outputs.push(MiterOutput {
            name: format!("po {name}"),
            spec: spec_l,
            impl_lit: impl_l,
            miter,
        });
    }
    for (i, &cid) in ff_cells.iter().enumerate() {
        let Some(&inet) = nl.cells.get(cid as usize).and_then(|c| c.ins.first()) else {
            return Err(shape(format!("ff{i}.d"), "FF cell without d net"));
        };
        let spec_l = spec_of(&spec, circ.ffs[i].0);
        let impl_l = net_lit.get(inet as usize).copied().unwrap_or(Lit::FALSE);
        let miter = aig.xor(spec_l, impl_l);
        outputs.push(MiterOutput {
            name: format!("ff{i}.d"),
            spec: spec_l,
            impl_lit: impl_l,
            miter,
        });
    }

    Ok(Miter {
        aig,
        inputs,
        n_pis,
        outputs,
        merged_luts,
        unmerged_luts,
    })
}

/// Replay one input assignment through the netlist view with plain bools —
/// an evaluator *independent* of the miter construction, used to render
/// (and effectively re-verify) every counterexample witness.  Returns
/// per-net values; `None` only on malformed shapes.
pub fn replay_netlist(
    nl: &Netlist,
    idx: &NetlistIndex,
    view: &EquivView<'_>,
    pi_vals: &[bool],
    ff_vals: &[bool],
) -> Option<Vec<bool>> {
    if nl.inputs.len() != pi_vals.len() {
        return None;
    }
    let mut val = vec![false; nl.nets.len()];
    for (i, &cid) in nl.inputs.iter().enumerate() {
        let &net = nl.cells.get(cid as usize)?.outs.first()?;
        val[net as usize] = pi_vals[i];
    }
    let mut ffi = 0usize;
    for cell in &nl.cells {
        match cell.kind {
            CellKind::Ff => {
                let &net = cell.outs.first()?;
                val[net as usize] = ff_vals.get(ffi).copied().unwrap_or(false);
                ffi += 1;
            }
            CellKind::Const(v) => {
                let &net = cell.outs.first()?;
                val[net as usize] = v;
            }
            _ => {}
        }
    }
    let mut paths: Vec<Option<[OperandPath; 2]>> = Vec::new();
    if let EquivView::Packed(packing) = view {
        paths = vec![None; nl.cells.len()];
        for alm in &packing.alms {
            for (bi, &c) in alm.adder_bits.iter().enumerate() {
                if let (Some(slot), Some(&p)) =
                    (paths.get_mut(c as usize), alm.operand_paths.get(bi))
                {
                    *slot = Some(p);
                }
            }
        }
    }
    for &cid in idx.topo_order() {
        let cell = nl.cells.get(cid as usize)?;
        match cell.kind {
            CellKind::Lut { truth, .. } => {
                let mut row = 0usize;
                for (i, &n) in cell.ins.iter().enumerate() {
                    let v = val.get(n as usize).copied().unwrap_or(false);
                    row |= (v as usize) << i;
                }
                let &out = cell.outs.first()?;
                val[out as usize] = truth >> row & 1 == 1;
            }
            CellKind::AdderBit { .. } => {
                let get_in = |pin: usize| -> bool {
                    cell.ins
                        .get(pin)
                        .and_then(|&n| val.get(n as usize))
                        .copied()
                        .unwrap_or(false)
                };
                let mut a = get_in(0);
                let mut b = get_in(1);
                let c = get_in(2);
                if let Some(Some([pa, pb])) = paths.get(cid as usize) {
                    let resolve = |p: OperandPath, net_v: bool| -> bool {
                        match p {
                            OperandPath::Const
                            | OperandPath::RouteThrough
                            | OperandPath::ZBypass => net_v,
                            OperandPath::AbsorbedLut(l) => nl
                                .cells
                                .get(l as usize)
                                .and_then(|c| c.outs.first())
                                .and_then(|&n| val.get(n as usize))
                                .copied()
                                .unwrap_or(net_v),
                        }
                    };
                    a = resolve(*pa, a);
                    b = resolve(*pb, b);
                }
                let sum = a ^ b ^ c;
                let cout = (a & b) | (a & c) | (b & c);
                if let Some(&sn) = cell.outs.first() {
                    val[sn as usize] = sum;
                }
                if let Some(&cn) = cell.outs.get(1) {
                    val[cn as usize] = cout;
                }
            }
            _ => {}
        }
    }
    Some(val)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::techmap::{map_circuit, MapOpts};

    fn adder_circ() -> Circuit {
        let mut c = Circuit::new("eqm");
        let x = c.pi_bus("x", 4);
        let y = c.pi_bus("y", 4);
        let s = c.ripple_add(&x, &y);
        c.po_bus("s", &s);
        let m = c.aig.maj3(x[0], y[0], x[1]);
        c.po("m", m);
        c
    }

    #[test]
    fn healthy_mapped_miter_folds_every_output() {
        let c = adder_circ();
        let nl = map_circuit(&c, &MapOpts::default());
        let idx = NetlistIndex::build(&nl);
        let m = build(&c, &nl, &idx, &EquivView::Mapped).expect("miter");
        assert_eq!(m.outputs.len(), c.pos.len());
        for o in &m.outputs {
            assert_eq!(o.miter, Lit::FALSE, "{} did not fold", o.name);
        }
        assert!(m.merged_luts + m.unmerged_luts > 0 || nl.num_luts() == 0);
    }

    #[test]
    fn corrupted_truth_mask_breaks_folding() {
        let c = adder_circ();
        let mut nl = map_circuit(&c, &MapOpts::default());
        let lut = nl
            .cells
            .iter()
            .position(|cl| matches!(cl.kind, CellKind::Lut { .. }))
            .expect("a lut");
        if let CellKind::Lut { truth, .. } = &mut nl.cells[lut].kind {
            *truth ^= 1;
        }
        let idx = NetlistIndex::build(&nl);
        let m = build(&c, &nl, &idx, &EquivView::Mapped).expect("miter");
        // The corrupted cone must not fold to constant-equal everywhere
        // (it may fold to constant TRUE, which is a detected mismatch).
        assert!(
            m.outputs.iter().any(|o| o.miter != Lit::FALSE),
            "flipped truth bit still folded clean"
        );
    }

    #[test]
    fn lut_name_hints_parse() {
        assert_eq!(parse_lut_root("lut_n42"), Some((42, false)));
        assert_eq!(parse_lut_root("lut_n7_neg"), Some((7, true)));
        assert_eq!(parse_lut_root("inv_n3"), Some((3, true)));
        assert_eq!(parse_lut_root("fa_0_1"), None);
        assert_eq!(parse_lut_root("lut_nxyz"), None);
    }

    #[test]
    fn replay_matches_circuit_simulation() {
        let c = adder_circ();
        let nl = map_circuit(&c, &MapOpts::default());
        let idx = NetlistIndex::build(&nl);
        for pat in 0u32..64 {
            let pis: Vec<bool> = (0..8).map(|i| pat.wrapping_mul(37) >> i & 1 == 1).collect();
            let want = c.simulate(&pis, &[]);
            let vals = replay_netlist(&nl, &idx, &EquivView::Mapped, &pis, &[]).expect("replay");
            for (i, &ocell) in nl.outputs.iter().enumerate() {
                let inet = nl.cells[ocell as usize].ins[0] as usize;
                assert_eq!(vals[inet], want[i], "PO {i} under pattern {pat}");
            }
        }
    }
}
