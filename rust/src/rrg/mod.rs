//! Routing-resource graph (RRG): the shared substrate of the router and
//! the post-route timing path.  The graph build is deterministic per
//! (device, arch), which is what lets [`crate::check::audit_routing`]
//! rebuild it independently and re-derive pin taps when auditing a
//! routing.
//!
//! ## Node layout
//!
//! Every grid corner `(x, y)` of the device (including the I/O ring)
//! carries `W` horizontal and `W` vertical track nodes, one per channel
//! wire.  Node ids are a dense arena in
//! `dir (0 = H, 1 = V) x height x width x W` order:
//!
//! ```text
//! id(dir, x, y, t) = ((dir * H + y) * W_grid + x) * tracks + t
//! ```
//!
//! so all tracks of one channel segment are contiguous (cache-friendly for
//! the per-channel utilization reduction) and `decode` is three divisions.
//! Adjacency is a CSR table built once per (device, channel width):
//! horizontal tracks chain along x, vertical along y, and turns connect
//! track `t` to tracks `t` and `(t + 1) % W` of the crossing direction (a
//! Wilton-like twist, so track planes are not isolated).  Edge order in
//! the CSR rows is fixed, which pins the A* tie-breaking order and hence
//! the routed trees.
//!
//! Block pins are not materialized as nodes: [`RrGraph::pin_nodes`]
//! hashes a deterministic `fc`-fraction subset of the adjacent channel
//! corners per (location, salt), exactly like VPR's connection-block
//! flexibility.
//!
//! Because the edge pattern is translation-invariant, exact
//! congestion-free cost-to-target maps exist per node *class* rather
//! than per node: [`lookahead`] precomputes them once per
//! (device, channel width) — keyed by [`lookahead::cache_key`], never by
//! the netlist — and the router uses them as a sharper admissible A*
//! heuristic (see that module's docs for the admissibility argument).
//!
//! ## Cost model and the snapshot/reduce negotiation scheme
//!
//! [`CostState`] holds the PathFinder arrays: per-node occupancy
//! (`occ`), history cost (`hist`), a timing-criticality lane (`crit`,
//! rebuilt per iteration by the router; scales the history bump so
//! congestion on critical wiring resolves first), and the congestion
//! formula `(1 + hist) * (1 + overuse * pres_fac)` on top of a unit base
//! cost.
//! The parallel router treats one negotiation iteration as:
//!
//! 1. **rip-up** (serial, fixed net order): congested nets release their
//!    occupancy;
//! 2. **route** (parallel, in fixed waves of `route::WAVE` nets): each
//!    wave's nets run A* against the *frozen* `CostState` snapshot taken
//!    at wave start — workers never write shared state, so any shard
//!    assignment computes identical per-net routes — and the wave's
//!    occupancy commits in net order before the next wave;
//! 3. **reduce** (serial): history costs bump on overused nodes.
//!
//! Because routing a net is a pure function of (wave snapshot, net), wave
//! boundaries never depend on the worker count, and steps 1/3 plus every
//! commit run in a fixed order on one thread, the result is bit-identical
//! for any worker count — the contract `rust/tests/route_parallel.rs`
//! enforces.  Wave size trades negotiation fidelity (fresh occupancy)
//! against parallelism; see the `route` module docs for measurements.

use crate::arch::device::Device;
use crate::arch::device::Loc;
use crate::arch::Arch;

pub mod lookahead;

/// Per-track capacity (one wire per track node).
pub const NODE_CAP: f64 = 1.0;

/// The routing-resource graph: node arena + CSR adjacency.
pub struct RrGraph {
    /// Grid width including the I/O ring.
    pub width: usize,
    /// Grid height including the I/O ring.
    pub height: usize,
    /// Channel width W (tracks per direction per grid corner).
    pub tracks: usize,
    /// CSR row starts: `edge_start[id]..edge_start[id + 1]` indexes
    /// `edges` for node `id`.
    edge_start: Vec<u32>,
    /// CSR edge targets.
    edges: Vec<u32>,
}

impl RrGraph {
    /// Build the graph for a device and architecture (channel width).
    pub fn build(device: &Device, arch: &Arch) -> RrGraph {
        let w = device.width() as usize;
        let h = device.height() as usize;
        let tracks = (arch.routing.channel_width as usize).max(1);
        let n = 2 * w * h * tracks;
        let id = |dir: usize, x: usize, y: usize, t: usize| -> u32 {
            (((dir * h + y) * w + x) * tracks + t) as u32
        };
        let mut edge_start = Vec::with_capacity(n + 1);
        let mut edges: Vec<u32> = Vec::with_capacity(4 * n);
        edge_start.push(0u32);
        for dir in 0..2 {
            for y in 0..h {
                for x in 0..w {
                    for t in 0..tracks {
                        if dir == 0 {
                            // Horizontal: extend along x; turn onto V here.
                            if x + 1 < w {
                                edges.push(id(0, x + 1, y, t));
                            }
                            if x > 0 {
                                edges.push(id(0, x - 1, y, t));
                            }
                            edges.push(id(1, x, y, t));
                            edges.push(id(1, x, y, (t + 1) % tracks));
                        } else {
                            // Vertical: extend along y; turn onto H here.
                            if y + 1 < h {
                                edges.push(id(1, x, y + 1, t));
                            }
                            if y > 0 {
                                edges.push(id(1, x, y - 1, t));
                            }
                            edges.push(id(0, x, y, t));
                            edges.push(id(0, x, y, (t + 1) % tracks));
                        }
                        edge_start.push(edges.len() as u32);
                    }
                }
            }
        }
        RrGraph { width: w, height: h, tracks, edge_start, edges }
    }

    #[inline]
    pub fn node_id(&self, dir: usize, x: usize, y: usize, t: usize) -> usize {
        ((dir * self.height + y) * self.width + x) * self.tracks + t
    }

    /// Inverse of [`node_id`](Self::node_id): `(dir, x, y, t)`.
    #[inline]
    pub fn decode(&self, id: usize) -> (usize, usize, usize, usize) {
        let t = id % self.tracks;
        let rest = id / self.tracks;
        let x = rest % self.width;
        let rest = rest / self.width;
        let y = rest % self.height;
        let dir = rest / self.height;
        (dir, x, y, t)
    }

    pub fn num_nodes(&self) -> usize {
        2 * self.width * self.height * self.tracks
    }

    /// Fan-out of `id` in fixed CSR order.
    #[inline]
    pub fn neighbors(&self, id: usize) -> &[u32] {
        let lo = self.edge_start[id] as usize;
        let hi = self.edge_start[id + 1] as usize;
        &self.edges[lo..hi]
    }

    /// Admissible A* heuristic: Manhattan distance to the target corner.
    #[inline]
    pub fn heur(&self, id: usize, tx: usize, ty: usize) -> f64 {
        let (_, x, y, _) = self.decode(id);
        ((x as i64 - tx as i64).abs() + (y as i64 - ty as i64).abs()) as f64
    }

    /// Channel nodes a block pin can reach: a hashed `frac` subset of the
    /// tracks, spread over the four channel corners adjacent to the block
    /// (blocks have pins on all sides, so their taps must not pile onto a
    /// single grid point).  Deterministic in (location, salt).
    pub fn pin_nodes(&self, loc: Loc, frac: f64, salt: u64) -> Vec<usize> {
        let tracks = self.tracks;
        let n = ((tracks as f64 * frac).ceil() as usize).clamp(2, tracks) * 2;
        let mut v = Vec::with_capacity(n);
        let mut x = (loc.x as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((loc.y as u64).wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add(salt);
        let corners = [
            (loc.x as usize, loc.y as usize),
            (loc.x.saturating_sub(1) as usize, loc.y as usize),
            (loc.x as usize, loc.y.saturating_sub(1) as usize),
            (loc.x.saturating_sub(1) as usize, loc.y.saturating_sub(1) as usize),
        ];
        for _ in 0..n {
            x ^= x >> 27;
            x = x.wrapping_mul(0x94D049BB133111EB);
            let t = (x % tracks as u64) as usize;
            let (cx, cy) = corners[((x >> 17) % 4) as usize];
            let dir = ((x >> 33) & 1) as usize;
            if cx < self.width && cy < self.height {
                v.push(self.node_id(dir, cx, cy, t));
            }
        }
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Routed interconnect delay for a sink whose path uses `hops` wire
/// segments — the quantity post-route STA charges per net edge.
pub fn hop_delay(arch: &Arch, hops: usize) -> f64 {
    arch.delays.conn_block
        + (hops as f64 / arch.routing.segment_len as f64).ceil().max(1.0)
            * arch.delays.wire_segment
}

/// PathFinder negotiation state: per-node occupancy, history cost, and a
/// timing-criticality lane.
///
/// During the parallel routing phase this is a read-only snapshot; the
/// serial reduce phase applies occupancy deltas and history bumps.
///
/// The `crit` lane carries, per node, the max sink criticality of any net
/// currently routed through it.  The router rebuilds it every negotiation
/// iteration (clear + fixed-order max-accumulate over the committed
/// trees), and [`CostState::bump_history`] scales its increment by
/// `1 + crit` — congestion parked on timing-critical wiring accrues
/// history faster, so the slack-rich competitors detour first.  With
/// timing-driven routing off the lane stays all-zero and the bump reduces
/// to the classic `hist += hist_fac` bit-exactly.
#[derive(Clone, Debug)]
pub struct CostState {
    pub occ: Vec<u16>,
    pub hist: Vec<f32>,
    pub crit: Vec<f32>,
}

impl CostState {
    pub fn new(n_nodes: usize) -> CostState {
        CostState {
            occ: vec![0; n_nodes],
            hist: vec![0.0; n_nodes],
            crit: vec![0.0; n_nodes],
        }
    }

    /// Reset the criticality lane (start of a negotiation iteration).
    pub fn clear_crit(&mut self) {
        self.crit.iter_mut().for_each(|c| *c = 0.0);
    }

    /// Max-accumulate criticality `c` onto node `id`.  Order-independent,
    /// so fixed-order commits keep the lane deterministic.
    #[inline]
    pub fn note_crit(&mut self, id: usize, c: f32) {
        if c > self.crit[id] {
            self.crit[id] = c;
        }
    }

    /// PathFinder node cost: `(1 + hist) * (1 + overuse * pres_fac)` over
    /// a unit base cost.
    #[inline]
    pub fn node_cost(&self, id: usize, pres_fac: f64) -> f64 {
        let over = (self.occ[id] as f64 + 1.0 - NODE_CAP).max(0.0);
        (1.0 + self.hist[id] as f64) * (1.0 + over * pres_fac)
    }

    /// Is node `id` currently over capacity?
    #[inline]
    pub fn overused(&self, id: usize) -> bool {
        self.occ[id] as f64 > NODE_CAP
    }

    /// Accumulate history cost on every overused node; returns how many
    /// nodes are overused (0 = the iteration converged).  The increment is
    /// scaled by `1 + crit[id]` (exactly `hist_fac` while the criticality
    /// lane is all-zero — see the struct docs).
    pub fn bump_history(&mut self, hist_fac: f64) -> usize {
        let mut overused = 0usize;
        for id in 0..self.occ.len() {
            if self.occ[id] as f64 > NODE_CAP {
                overused += 1;
                self.hist[id] += (hist_fac * (1.0 + self.crit[id] as f64)) as f32;
            }
        }
        overused
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Arch, ArchVariant};

    fn graph() -> RrGraph {
        let mut arch = Arch::paper(ArchVariant::Baseline);
        arch.routing.channel_width = 6;
        RrGraph::build(&Device::new(4, 4), &arch)
    }

    #[test]
    fn id_decode_round_trip() {
        let g = graph();
        for id in 0..g.num_nodes() {
            let (d, x, y, t) = g.decode(id);
            assert_eq!(g.node_id(d, x, y, t), id);
        }
    }

    #[test]
    fn csr_covers_every_node_with_sane_degrees() {
        let g = graph();
        for id in 0..g.num_nodes() {
            let nbrs = g.neighbors(id);
            assert!((3..=4).contains(&nbrs.len()), "degree {} at {id}", nbrs.len());
            for &nb in nbrs {
                assert!((nb as usize) < g.num_nodes());
                assert_ne!(nb as usize, id);
            }
        }
    }

    #[test]
    fn edges_connect_adjacent_corners_only() {
        let g = graph();
        for id in 0..g.num_nodes() {
            let (_, x, y, _) = g.decode(id);
            for &nb in g.neighbors(id) {
                let (_, nx, ny, _) = g.decode(nb as usize);
                let d = (x as i64 - nx as i64).abs() + (y as i64 - ny as i64).abs();
                assert!(d <= 1, "edge jumps {d} corners");
            }
        }
    }

    #[test]
    fn pin_nodes_deterministic_and_in_range() {
        let g = graph();
        let a = g.pin_nodes(Loc::new(2, 2), 0.3, 99);
        let b = g.pin_nodes(Loc::new(2, 2), 0.3, 99);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.iter().all(|&n| n < g.num_nodes()));
        // Different salt spreads onto (generally) different taps.
        let c = g.pin_nodes(Loc::new(2, 2), 0.3, 100);
        assert_ne!(a, c);
    }

    #[test]
    fn cost_state_congestion_formula() {
        let mut cs = CostState::new(4);
        assert_eq!(cs.node_cost(0, 2.0), 1.0); // free node, no history
        cs.occ[1] = 1; // at capacity: no overuse yet
        assert_eq!(cs.node_cost(1, 2.0), 1.0);
        cs.occ[2] = 2; // one over
        assert!(cs.node_cost(2, 2.0) > cs.node_cost(1, 2.0));
        assert!(!cs.overused(1));
        assert!(cs.overused(2));
        let n = cs.bump_history(0.5);
        assert_eq!(n, 1);
        assert!(cs.node_cost(2, 2.0) > 3.0);
    }

    /// The criticality lane scales history accumulation and clears to the
    /// neutral (classic PathFinder) bump.
    #[test]
    fn crit_lane_scales_history_bump() {
        let mut cs = CostState::new(3);
        cs.occ[0] = 2;
        cs.occ[1] = 2;
        cs.note_crit(1, 1.0);
        cs.note_crit(1, 0.5); // max-accumulate keeps the larger value
        assert_eq!(cs.crit[1], 1.0);
        let n = cs.bump_history(0.5);
        assert_eq!(n, 2);
        assert_eq!(cs.hist[0], 0.5); // neutral node: classic bump
        assert_eq!(cs.hist[1], 1.0); // fully critical node: doubled
        cs.clear_crit();
        assert!(cs.crit.iter().all(|&c| c == 0.0));
        cs.bump_history(0.5);
        assert_eq!(cs.hist[0], 1.0);
        assert_eq!(cs.hist[1], 1.5);
    }

    #[test]
    fn hop_delay_monotone_in_hops() {
        let arch = Arch::paper(ArchVariant::Baseline);
        assert!(hop_delay(&arch, 9) > hop_delay(&arch, 2));
        assert!(hop_delay(&arch, 1) > 0.0);
    }
}
