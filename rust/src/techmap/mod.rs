//! Technology mapping: AIG -> K-LUT netlist (the ABC substitute).

pub mod aig;
pub mod mapper;

pub use mapper::{map_circuit, map_circuit_with, MapOpts};
