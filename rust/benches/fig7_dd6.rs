//! Bench harness regenerating the paper's Fig. 7 (DD5 vs DD6).
//! Run: cargo bench --bench fig7_dd6   (DDUTY_FULL=1 for full effort)
use std::time::Instant;
use double_duty::report::{self, ExpOpts};

fn main() {
    let opts = if std::env::var("DDUTY_FULL").is_ok() {
        ExpOpts::default()
    } else {
        ExpOpts::quick()
    };
    let t0 = Instant::now();
    report::fig7(&opts).print();
    println!();
    println!("[fig7_dd6] regenerated in {:.1} s", t0.elapsed().as_secs_f64());
}
