//! Logic-block clustering: greedy seed-based ALM grouping under the LB
//! external-input budget, with carry-chain macro handling.
//!
//! The expensive step is attraction scoring: for every candidate ALM the
//! clusterer counts shared nets and simulates the LB's external-input set
//! after absorption.  Candidates are gathered in a fixed deterministic
//! order and scored independently (each score is a pure function of the
//! frozen LB state), so wide scans shard across workers
//! ([`crate::coordinator::parallel_indexed`]); the winner reduction and
//! the commit stay serial and in fixed order, which keeps the clustering
//! bit-identical for any worker count.

use std::collections::{HashMap, HashSet};

use crate::arch::Arch;
use crate::coordinator::parallel_indexed;
use crate::netlist::{Netlist, NetId};

use super::{PackOpts, PackedAlm, Unrelated};

/// Minimum candidate-scan width before the scorer spins up workers.
/// Each growth step pays a scoped-thread spawn/join, so the bar is set
/// where scoring work (a net-sharing count plus a simulated input-set
/// union per candidate) clearly dwarfs thread startup; narrower scans run
/// serially with identical results.
const PAR_MIN_CANDS: usize = 256;

/// One packed logic block.
#[derive(Clone, Debug, Default)]
pub struct PackedLb {
    /// Member ALM indices (into `Packing::alms`), <= 10.
    pub alms: Vec<usize>,
    /// Distinct nets entering the LB from outside.
    pub inputs: HashSet<NetId>,
    /// Nets driven inside the LB that have outside sinks.
    pub outputs: HashSet<NetId>,
    /// Chain ids passing through this LB.
    pub chains: Vec<u32>,
}

/// Cluster ALMs into logic blocks. Returns the LBs and, per chain, the
/// ordered LB indices it spans (the placement macro).
pub fn cluster_lbs(
    nl: &Netlist,
    arch: &Arch,
    alms: &[PackedAlm],
    chain_alms: &[Vec<usize>],
    opts: &PackOpts,
    jobs: usize,
) -> (Vec<PackedLb>, Vec<Vec<usize>>) {
    let cap = arch.lb.alms as usize;
    let pin_budget =
        (arch.lb.inputs as f64 * arch.lb.target_ext_pin_util).floor() as usize;

    // Which nets are driven by which ALM (to distinguish feedback from
    // external inputs).
    let mut net_driver_alm: HashMap<NetId, usize> = HashMap::new();
    for (ai, alm) in alms.iter().enumerate() {
        for &net in &alm.outputs {
            net_driver_alm.insert(net, ai);
        }
    }
    // Attraction index: net -> ALMs consuming it.
    let mut net_consumers: HashMap<NetId, Vec<usize>> = HashMap::new();
    for (ai, alm) in alms.iter().enumerate() {
        for &net in alm.gen_inputs.iter().chain(alm.z_inputs.iter()) {
            net_consumers.entry(net).or_default().push(ai);
        }
    }

    let alm_nets = |ai: usize| -> Vec<NetId> {
        alms[ai]
            .gen_inputs
            .iter()
            .chain(alms[ai].z_inputs.iter())
            .chain(alms[ai].outputs.iter())
            .copied()
            .collect()
    };

    // External inputs an LB would have after adding `ai`.
    let inputs_with = |lb: &PackedLb, members: &HashSet<usize>, ai: usize| -> usize {
        let mut inputs = lb.inputs.clone();
        // Adding ai may turn some existing inputs into feedback.
        for &net in &alms[ai].outputs {
            inputs.remove(&net);
        }
        for &net in alms[ai].gen_inputs.iter().chain(alms[ai].z_inputs.iter()) {
            let internal = net_driver_alm
                .get(&net)
                .map(|d| members.contains(d) || *d == ai)
                .unwrap_or(false);
            if !internal {
                inputs.insert(net);
            }
        }
        inputs.len()
    };

    let mut assigned = vec![false; alms.len()];
    let mut lbs: Vec<PackedLb> = Vec::new();
    let mut alm_lb: Vec<usize> = vec![usize::MAX; alms.len()];

    let mut add_alm = |lb: &mut PackedLb, members: &mut HashSet<usize>, ai: usize,
                       assigned: &mut Vec<bool>, alm_lb: &mut Vec<usize>, lb_idx: usize| {
        lb.alms.push(ai);
        members.insert(ai);
        assigned[ai] = true;
        alm_lb[ai] = lb_idx;
        if let Some(ch) = alms[ai].chain {
            if !lb.chains.contains(&ch) {
                lb.chains.push(ch);
            }
        }
        // Recompute inputs/outputs incrementally.
        for &net in &alms[ai].outputs {
            lb.inputs.remove(&net);
            lb.outputs.insert(net);
        }
        for &net in alms[ai].gen_inputs.iter().chain(alms[ai].z_inputs.iter()) {
            let internal = net_driver_alm
                .get(&net)
                .map(|d| members.contains(d))
                .unwrap_or(false);
            if !internal {
                lb.inputs.insert(net);
            }
        }
    };

    // --- Chain ALM runs first: they are placement macros. ------------------
    let mut chain_macros: Vec<Vec<usize>> = vec![Vec::new(); chain_alms.len()];
    for (ch, alms_of_chain) in chain_alms.iter().enumerate() {
        for seg in alms_of_chain.chunks(cap) {
            let lb_idx = lbs.len();
            let mut lb = PackedLb::default();
            let mut members: HashSet<usize> = HashSet::new();
            for &ai in seg {
                // Chain segments ignore the pin budget check: carry chains
                // are pin-light and must stay contiguous (VPR does the same
                // for carry macros).
                add_alm(&mut lb, &mut members, ai, &mut assigned, &mut alm_lb, lb_idx);
            }
            chain_macros[ch].push(lb_idx);
            lbs.push(lb);
        }
    }

    // --- Fill chain LBs and build the rest greedily. -----------------------
    // Candidate queue: unassigned ALMs, highest connectivity first.
    let mut queue: Vec<usize> = (0..alms.len()).filter(|&i| !assigned[i]).collect();
    queue.sort_by_key(|&i| std::cmp::Reverse(alms[i].gen_inputs.len() + alms[i].outputs.len()));

    // Helper: grow one LB to capacity by attraction.
    let grow = |lb_idx: usize,
                lbs: &mut Vec<PackedLb>,
                assigned: &mut Vec<bool>,
                alm_lb: &mut Vec<usize>| {
        let mut members: HashSet<usize> = lbs[lb_idx].alms.iter().copied().collect();
        while lbs[lb_idx].alms.len() < cap {
            // Attracted candidates: consumers/drivers of nets in the LB,
            // gathered in deterministic (net, consumers-then-driver) scan
            // order, first occurrence kept (re-scoring a duplicate can
            // never win the strict-improvement reduction below).
            let mut nets: Vec<NetId> = lbs[lb_idx]
                .inputs
                .iter()
                .chain(lbs[lb_idx].outputs.iter())
                .copied()
                .collect();
            nets.sort_unstable(); // deterministic scan order
            let mut cand: Vec<usize> = Vec::new();
            {
                let mut seen: HashSet<usize> = HashSet::new();
                let mut push = |ai: usize| {
                    if !assigned[ai] && alms[ai].chain.is_none() && seen.insert(ai) {
                        cand.push(ai);
                    }
                };
                for &net in &nets {
                    if let Some(cs) = net_consumers.get(&net) {
                        for &ai in cs {
                            push(ai);
                        }
                    }
                    if let Some(&d) = net_driver_alm.get(&net) {
                        push(d);
                    }
                }
            }
            // Score each candidate against the frozen LB state: shared-net
            // count plus the external-input budget after absorption.  Pure
            // per candidate, so wide scans shard across workers.
            let lb_ref: &PackedLb = &lbs[lb_idx];
            let members_ref = &members;
            let score = |ai: usize| -> (usize, bool) {
                let shared = alm_nets(ai)
                    .iter()
                    .filter(|n| lb_ref.inputs.contains(n) || lb_ref.outputs.contains(n))
                    .count();
                if shared == 0 {
                    return (0, false);
                }
                (shared, inputs_with(lb_ref, members_ref, ai) <= pin_budget)
            };
            let scores: Vec<(usize, bool)> = if jobs > 1 && cand.len() >= PAR_MIN_CANDS {
                parallel_indexed(cand.len(), jobs, |i| score(cand[i]))
            } else {
                cand.iter().map(|&ai| score(ai)).collect()
            };
            // Serial reduce in scan order: earliest candidate attaining
            // the maximum shared count wins (the sequential tie-break).
            let mut best: Option<(usize, usize)> = None; // (score, ai)
            for (&ai, &(shared, ok)) in cand.iter().zip(scores.iter()) {
                if shared == 0 || !ok {
                    continue;
                }
                if best.map_or(true, |(s, _)| shared > s) {
                    best = Some((shared, ai));
                }
            }
            let Some((_, ai)) = best else { break };
            let mut lb = std::mem::take(&mut lbs[lb_idx]);
            add_alm(&mut lb, &mut members, ai, assigned, alm_lb, lb_idx);
            lbs[lb_idx] = lb;
        }
    };

    // Fill chain LBs that still have room.
    for lb_idx in 0..lbs.len() {
        grow(lb_idx, &mut lbs, &mut assigned, &mut alm_lb);
    }

    // New LBs from remaining ALMs.
    for qi in 0..queue.len() {
        let seed = queue[qi];
        if assigned[seed] {
            continue;
        }
        let lb_idx = lbs.len();
        let mut lb = PackedLb::default();
        let mut members: HashSet<usize> = HashSet::new();
        add_alm(&mut lb, &mut members, seed, &mut assigned, &mut alm_lb, lb_idx);
        lbs.push(lb);
        grow(lb_idx, &mut lbs, &mut assigned, &mut alm_lb);
        // Unrelated fill if allowed: top up with arbitrary ALMs.
        if opts.unrelated != Unrelated::Off {
            let mut members: HashSet<usize> = lbs[lb_idx].alms.iter().copied().collect();
            let mut qj = qi + 1;
            while lbs[lb_idx].alms.len() < cap && qj < queue.len() {
                let ai = queue[qj];
                qj += 1;
                if assigned[ai] || alms[ai].chain.is_some() {
                    continue;
                }
                if inputs_with(&lbs[lb_idx], &members, ai) <= pin_budget {
                    let mut lb = std::mem::take(&mut lbs[lb_idx]);
                    add_alm(&mut lb, &mut members, ai, &mut assigned, &mut alm_lb, lb_idx);
                    lbs[lb_idx] = lb;
                    // In Auto mode stop at one unrelated top-up per LB pass
                    // to avoid destroying locality; On packs to the brim.
                    if opts.unrelated == Unrelated::Auto {
                        break;
                    }
                }
            }
        }
    }

    (lbs, chain_macros)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchVariant;
    use crate::pack::{pack, PackOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn packed(w: usize, v: ArchVariant) -> crate::pack::Packing {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", w);
        let y = c.pi_bus("y", w);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Dadda);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        pack(&nl, &Arch::paper(v), &PackOpts::default())
    }

    #[test]
    fn lbs_hold_at_most_ten_alms() {
        let p = packed(8, ArchVariant::Baseline);
        for lb in &p.lbs {
            assert!(lb.alms.len() <= 10);
        }
    }

    #[test]
    fn every_alm_in_exactly_one_lb() {
        let p = packed(8, ArchVariant::Dd5);
        let mut seen = vec![false; p.alms.len()];
        for lb in &p.lbs {
            for &ai in &lb.alms {
                assert!(!seen[ai], "ALM {ai} in two LBs");
                seen[ai] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn chain_macros_cover_all_chain_alms() {
        let p = packed(8, ArchVariant::Baseline);
        for (ch, lbs) in p.chain_macros.iter().enumerate() {
            // Each macro LB must actually contain the chain.
            for &lb in lbs {
                assert!(p.lbs[lb].chains.contains(&(ch as u32)));
            }
        }
    }

    #[test]
    fn feedback_nets_not_counted_as_inputs() {
        let p = packed(6, ArchVariant::Baseline);
        for lb in &p.lbs {
            for net in &lb.inputs {
                assert!(!lb.outputs.contains(net),
                        "net counted both input and output of one LB");
            }
        }
    }
}
