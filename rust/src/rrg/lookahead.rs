//! Per-device RRG routing lookahead: exact congestion-free
//! cost-to-target maps, indexed by node *class* instead of node id.
//!
//! ## Why classes, and why this is exact
//!
//! The RRG ([`super::RrGraph`]) is translation-invariant away from the
//! grid edge: every corner carries the same H/V track bundle and the same
//! chain/turn edge pattern.  Under the router's unit base cost (every
//! node entered costs at least 1 — see [`crate::rrg::CostState`]), a
//! cheapest congestion-free path between two corners can always be
//! chosen *monotone*: it never leaves the bounding box of its endpoints,
//! because any detour adds nodes without unlocking edges a monotone path
//! lacks.  The bounding box of any (node, target) pair lies inside the
//! device, so the minimal hop count from a node at offset
//! `(Δx, Δy)` from a target corner depends only on
//! `(direction, |Δx|, |Δy|)` — the node's *class* — and not on where in
//! the grid the pair sits.  One backward BFS per device therefore yields
//! the exact minimal number of nodes entered after leaving a class-`
//! (dir, |Δx|, |Δy|)` node until some node at the target corner is
//! reached, for *every* class at once.
//!
//! ## Construction
//!
//! [`Lookahead::build`] runs a multi-source backward BFS from all
//! `2 * tracks` nodes at grid corner `(0, 0)` over the *reversed* CSR
//! (the Wilton-like turn twist `H(t) → V((t+1) % W)` has no same-track
//! mirror, so forward rows cannot stand in for reverse adjacency), then
//! folds the per-node distances to per-`(dir, |Δy|, |Δx|)` minima over
//! tracks.  Distances are hop counts: a target node scores 0 and each
//! reverse relaxation adds 1, so `dist` is "nodes entered after this
//! one", matching what the A* still has to pay.
//!
//! ## Admissibility
//!
//! [`Lookahead::query`] returns the minimum class distance over the four
//! saturated channel corners a sink's pin taps can occupy (the same
//! corner set [`super::RrGraph::pin_nodes`] draws from), minimized over
//! track and direction at the target.  The true target set is a subset
//! of those corners' nodes, and every node entered costs at least 1
//! under the criticality blend `(1 - c) * node_cost + c` with
//! `node_cost >= 1`, so the query never exceeds the true remaining path
//! cost: it is an admissible A* heuristic, and a strictly better-informed
//! one than the Manhattan bound it replaces (it prices the mandatory
//! turn between directions).  Note it is *not* pointwise >= Manhattan:
//! the legacy heuristic measured to the block corner `(tx, ty)` itself
//! and could overshoot a tap at a saturated corner by up to 2; the
//! lookahead measures to the real tap corners.
//!
//! ## Cache key
//!
//! The map depends only on `(width, height, tracks)` — the device grid
//! and the arch's channel width — hashed together with
//! [`LOOKAHEAD_VERSION`] by [`cache_key`].  It is independent of the
//! netlist, placement, and cost state, which is what makes the
//! process-global memo ([`shared`]) and the on-disk artifact
//! ([`crate::flow::diskcache::DiskCache::load_lookahead`]) safe to share
//! across benchmarks, seeds, and runs.  Bump the version constant if the
//! RRG edge pattern or the distance semantics ever change.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex, OnceLock};

use super::RrGraph;

/// Serialization / memo-key version of the lookahead map.  Participates
/// in [`cache_key`], so stale disk artifacts from an older edge pattern
/// miss instead of corrupting a run.
pub const LOOKAHEAD_VERSION: u32 = 1;

/// Per-device class-distance map: `dist[(dir * height + ady) * width +
/// adx]` is the exact minimal number of RRG nodes entered after a
/// direction-`dir` node at offset `(adx, ady)` from a target corner
/// until some node at that corner is reached (`u16::MAX` = unreachable,
/// which a connected RRG never produces).
pub struct Lookahead {
    width: usize,
    height: usize,
    tracks: usize,
    dist: Vec<u16>,
}

impl std::fmt::Debug for Lookahead {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Lookahead")
            .field("width", &self.width)
            .field("height", &self.height)
            .field("tracks", &self.tracks)
            .field("classes", &self.dist.len())
            .finish()
    }
}

impl Lookahead {
    /// Build the class-distance map for one RRG (see the module docs for
    /// the exactness argument).  Deterministic in the graph.
    pub fn build(graph: &RrGraph) -> Lookahead {
        let n = graph.num_nodes();
        // Reverse CSR.  The turn twist `H(t) -> V((t+1) % W)` is
        // track-asymmetric, so the forward rows are not their own
        // reverse adjacency.
        let mut row_start = vec![0u32; n + 1];
        for &e in &graph.edges {
            row_start[e as usize + 1] += 1;
        }
        for i in 0..n {
            row_start[i + 1] += row_start[i];
        }
        let mut rev_edges = vec![0u32; graph.edges.len()];
        let mut cursor: Vec<u32> = row_start.clone();
        for u in 0..n {
            let lo = graph.edge_start[u] as usize;
            let hi = graph.edge_start[u + 1] as usize;
            for &v in &graph.edges[lo..hi] {
                let slot = cursor[v as usize] as usize;
                rev_edges[slot] = u as u32;
                cursor[v as usize] += 1;
            }
        }

        // Multi-source backward BFS: every node at corner (0, 0) (both
        // directions, all tracks) is a target at distance 0; relaxing a
        // reverse edge adds one entered node.
        let mut d = vec![u16::MAX; n];
        let mut queue: VecDeque<u32> = VecDeque::new();
        for dir in 0..2 {
            for t in 0..graph.tracks {
                let id = graph.node_id(dir, 0, 0, t);
                d[id] = 0;
                queue.push_back(id as u32);
            }
        }
        while let Some(v) = queue.pop_front() {
            let nd = d[v as usize].saturating_add(1);
            if nd == u16::MAX {
                continue;
            }
            let lo = row_start[v as usize] as usize;
            let hi = row_start[v as usize + 1] as usize;
            for &u in &rev_edges[lo..hi] {
                if d[u as usize] == u16::MAX {
                    d[u as usize] = nd;
                    queue.push_back(u);
                }
            }
        }

        // Fold node distances to class minima over tracks.
        let (w, h) = (graph.width, graph.height);
        let mut dist = vec![u16::MAX; 2 * w * h];
        for (id, &dv) in d.iter().enumerate() {
            let (dir, x, y, _) = graph.decode(id);
            let c = (dir * h + y) * w + x;
            if dv < dist[c] {
                dist[c] = dv;
            }
        }
        Lookahead { width: w, height: h, tracks: graph.tracks, dist }
    }

    /// Reassemble a map from raw parts (disk load, mutation tests).
    /// Shape-checked: `None` unless `dist.len() == 2 * width * height`
    /// and all dimensions are nonzero.
    pub fn from_raw(
        width: usize,
        height: usize,
        tracks: usize,
        dist: Vec<u16>,
    ) -> Option<Lookahead> {
        if width == 0 || height == 0 || tracks == 0 || dist.len() != 2 * width * height {
            return None;
        }
        Some(Lookahead { width, height, tracks, dist })
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn tracks(&self) -> usize {
        self.tracks
    }

    /// Raw class distances (serialization; row layout in the type docs).
    pub fn dist(&self) -> &[u16] {
        &self.dist
    }

    /// Does this map describe the same grid as `graph`?
    pub fn matches(&self, graph: &RrGraph) -> bool {
        self.width == graph.width && self.height == graph.height && self.tracks == graph.tracks
    }

    /// Admissible remaining-cost estimate from node `node` to the sink
    /// pins of a block at grid location `(tx, ty)`: the minimum class
    /// distance over the four saturated channel corners pin taps can
    /// occupy (see the module docs).  An impossible `u16::MAX` entry
    /// degrades to 0.0 — still admissible — rather than poisoning the
    /// search with infinities.
    #[inline]
    pub fn query(&self, node: usize, tx: usize, ty: usize) -> f64 {
        let rest = node / self.tracks;
        let x = rest % self.width;
        let rest = rest / self.width;
        let y = rest % self.height;
        let dir = rest / self.height;
        let cx = [tx, tx.saturating_sub(1)];
        let cy = [ty, ty.saturating_sub(1)];
        let mut best = u16::MAX;
        for &ux in &cx {
            for &uy in &cy {
                let adx = x.abs_diff(ux);
                let ady = y.abs_diff(uy);
                if adx < self.width && ady < self.height {
                    let dv = self.dist[(dir * self.height + ady) * self.width + adx];
                    if dv < best {
                        best = dv;
                    }
                }
            }
        }
        if best == u16::MAX {
            0.0
        } else {
            best as f64
        }
    }
}

/// Memo / disk-cache key for a lookahead map: depends only on the grid
/// dimensions, the channel width, and [`LOOKAHEAD_VERSION`] — never on
/// the netlist (see the module docs).
pub fn cache_key(width: usize, height: usize, tracks: usize) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    LOOKAHEAD_VERSION.hash(&mut h);
    width.hash(&mut h);
    height.hash(&mut h);
    tracks.hash(&mut h);
    h.finish()
}

// Keyed only by `cache_key` lookups/inserts — never iterated, so the
// determinism lint's hash-iteration concern does not apply.
static SHARED: OnceLock<Mutex<HashMap<u64, Arc<Lookahead>>>> = OnceLock::new();

/// Process-global memo: build the map for `graph`'s dimensions at most
/// once per process and share it across nets, seeds, and benchmarks.
/// The flow's [`crate::flow::engine::ArtifactCache`] layers the on-disk
/// artifact store on top of this.
pub fn shared(graph: &RrGraph) -> Arc<Lookahead> {
    let key = cache_key(graph.width, graph.height, graph.tracks);
    let map = SHARED.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = map.lock().unwrap();
    if let Some(m) = guard.get(&key) {
        return m.clone();
    }
    let la = Arc::new(Lookahead::build(graph));
    guard.insert(key, la.clone());
    la
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::device::Device;
    use crate::arch::{Arch, ArchVariant};

    /// A graph over a `w x h` interior-LB device (grid is `w+2 x h+2`
    /// with the I/O ring).
    fn graph(w: u16, h: u16, tracks: u32) -> RrGraph {
        let mut arch = Arch::paper(ArchVariant::Baseline);
        arch.routing.channel_width = tracks;
        RrGraph::build(&Device::new(w, h), &arch)
    }

    /// The closed form the BFS must reproduce: the cheapest monotone
    /// path spends one node per grid step plus one turn node iff both a
    /// horizontal and a vertical leg are needed (or the node's own
    /// direction cannot take the only leg).
    fn closed_form(dir: usize, dx: usize, dy: usize) -> u16 {
        match (dir, dx, dy) {
            (_, 0, 0) => 0,
            (0, dx, 0) => dx as u16,
            (0, 0, dy) => (dy + 1) as u16,
            (1, 0, dy) => dy as u16,
            (1, dx, 0) => (dx + 1) as u16,
            (_, dx, dy) => (dx + dy + 1) as u16,
        }
    }

    #[test]
    fn bfs_matches_closed_form_everywhere() {
        let g = graph(7, 5, 4);
        let la = Lookahead::build(&g);
        for dir in 0..2 {
            for dy in 0..g.height {
                for dx in 0..g.width {
                    let got = la.dist()[(dir * g.height + dy) * g.width + dx];
                    assert_eq!(
                        got,
                        closed_form(dir, dx, dy),
                        "class (dir {dir}, dx {dx}, dy {dy})"
                    );
                }
            }
        }
    }

    /// Brute-force admissibility: for sampled targets, the query never
    /// exceeds the true hop distance from any node to that target's
    /// actual pin-corner node set (forward BFS ground truth).
    #[test]
    fn query_is_admissible_against_forward_bfs() {
        let g = graph(6, 6, 3);
        let la = Lookahead::build(&g);
        for &(tx, ty) in &[(1usize, 1usize), (3, 4), (5, 5)] {
            // True distance-to-target-set by backward BFS over forward
            // edges is awkward; equivalently BFS forward from every node
            // is O(n^2) but the graph is tiny.
            let corners = [
                (tx, ty),
                (tx.saturating_sub(1), ty),
                (tx, ty.saturating_sub(1)),
                (tx.saturating_sub(1), ty.saturating_sub(1)),
            ];
            let target = |id: usize| -> bool {
                let (_, x, y, _) = g.decode(id);
                corners.iter().any(|&(cx, cy)| cx == x && cy == y)
            };
            for start in 0..g.num_nodes() {
                // Forward BFS from `start` until any target node.
                let mut dist = vec![u32::MAX; g.num_nodes()];
                let mut q = std::collections::VecDeque::new();
                dist[start] = 0;
                q.push_back(start);
                let mut truth = u32::MAX;
                'bfs: while let Some(v) = q.pop_front() {
                    if target(v) {
                        truth = dist[v];
                        break 'bfs;
                    }
                    for &nb in g.neighbors(v) {
                        let u = nb as usize;
                        if dist[u] == u32::MAX {
                            dist[u] = dist[v] + 1;
                            q.push_back(u);
                        }
                    }
                }
                assert!(truth != u32::MAX, "disconnected RRG");
                assert!(
                    la.query(start, tx, ty) <= truth as f64,
                    "inadmissible at node {start} target ({tx},{ty}): \
                     query {} > true {truth}",
                    la.query(start, tx, ty)
                );
            }
        }
    }

    #[test]
    fn query_zero_at_target_corner() {
        let g = graph(5, 5, 3);
        let la = Lookahead::build(&g);
        for dir in 0..2 {
            for t in 0..g.tracks {
                assert_eq!(la.query(g.node_id(dir, 2, 2, t), 2, 2), 0.0);
            }
        }
    }

    #[test]
    fn from_raw_shape_checked() {
        // Device::new(4, 4) grids to 6x6 with the I/O ring, so round-trip
        // through the map's own dims, not the LB counts.
        let g = graph(4, 4, 3);
        let la = Lookahead::build(&g);
        let (w, h, t) = (la.width(), la.height(), la.tracks());
        let d = la.dist().to_vec();
        assert!(Lookahead::from_raw(w, h, t, d.clone()).is_some());
        assert!(Lookahead::from_raw(w, h + 1, t, d.clone()).is_none());
        assert!(Lookahead::from_raw(0, h, t, d).is_none());
        assert!(Lookahead::from_raw(w, h, t, vec![0u16; 3]).is_none());
    }

    #[test]
    fn shared_memoizes_per_dimension() {
        let g = graph(4, 4, 3);
        let a = shared(&g);
        let b = shared(&g);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.matches(&g));
        let g2 = graph(5, 4, 3);
        let c = shared(&g2);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn cache_key_separates_dimensions() {
        assert_ne!(cache_key(4, 4, 3), cache_key(4, 4, 4));
        assert_ne!(cache_key(4, 4, 3), cache_key(4, 5, 3));
        assert_eq!(cache_key(6, 7, 8), cache_key(6, 7, 8));
    }
}
