//! Minimal drop-in replacement for the `anyhow` idioms this crate uses.
//!
//! The offline build environment has no crates.io access, so the crate is
//! std-only.  This module provides the small surface the code relies on: a
//! string-backed [`Error`], a [`Result`] alias defaulting its error type,
//! a [`Context`] extension for `Result`/`Option`, and the `anyhow!` /
//! `bail!` / `ensure!` macros (exported at the crate root, as
//! `macro_rules!` exports are).

use std::fmt;

/// A string-backed error.
///
/// Deliberately does *not* implement [`std::error::Error`]: that keeps the
/// blanket `impl<E: std::error::Error> From<E> for Error` below coherent
/// (the same trick `anyhow::Error` uses), so `?` converts any std error
/// into this type.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error/none case with `msg`.
    fn context<D: fmt::Display>(self, msg: D) -> Result<T>;
    /// Wrap the error/none case with a lazily built message.
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<u8> {
        let _ = std::fs::metadata("/definitely/not/a/path")?; // From<io::Error>
        Ok(0)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u8> = None;
        let e = none.context("missing thing").unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");

        let r: std::result::Result<u8, std::num::ParseIntError> = "x".parse();
        let e = r.with_context(|| "parsing x").unwrap_err();
        assert!(format!("{e}").starts_with("parsing x: "));
    }

    #[test]
    fn macros_build_errors() {
        fn f(ok: bool) -> Result<u8> {
            ensure!(ok, "flag was {ok}");
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", anyhow!("n={}", 3)), "n=3");
    }
}
