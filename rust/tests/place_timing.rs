//! Placement-side determinism contracts of the per-sink timing lane and
//! move-type diversity (ISSUE-5):
//!
//! * (a) per-sink timing-driven placement is bit-identical for any
//!   `PlaceOpts::sta_jobs` — the STA refreshes are jobs-invariant, so
//!   worker counts must never leak into the anneal;
//! * (b) all-zero criticality (timing lane gain 0) is bit-identical to
//!   the wirelength-only placer — the timing lane contributes *exactly*
//!   zero, not approximately;
//! * (c) a fixed-seed golden run proposes and accepts every move kind,
//!   keeps chain macros legal, and reproduces itself exactly;
//! * (d) `move_mix = 0` restores the uniform-only proposal pipeline;
//! * (e) chained cross-seed feedback (`--timing-route`) stays
//!   bit-identical across `--route-jobs` at the flow layer.

use double_duty::arch::{Arch, ArchVariant};
use double_duty::netlist::{Netlist, NetlistIndex, PackIndex};
use double_duty::pack::{pack, PackOpts, Packing};
use double_duty::place::{place, place_with, MoveKind, PlaceOpts, Placement};
use double_duty::synth::circuit::Circuit;
use double_duty::synth::multiplier::{soft_mul, AdderAlgo};
use double_duty::techmap::aig::Lit;
use double_duty::techmap::{map_circuit, MapOpts};

/// A multiplier plus one long carry chain: single-LB logic blocks *and* a
/// guaranteed multi-LB chain macro (48 bits >> the 20 adder bits per LB),
/// so every move kind has real work.
fn chainy_setup() -> (Netlist, Packing, Arch) {
    let mut c = Circuit::new("chainy");
    let x = c.pi_bus("x", 5);
    let y = c.pi_bus("y", 5);
    let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
    c.po_bus("p", &p);
    let a = c.pi_bus("a", 48);
    let b = c.pi_bus("b", 48);
    let ops: Vec<(Lit, Lit)> = a.iter().copied().zip(b.iter().copied()).collect();
    let (sums, cout) = c.add_chain(ops, Lit::FALSE);
    c.po_bus("s", &sums);
    c.po("co", cout);
    let nl = map_circuit(&c, &MapOpts::default());
    let arch = Arch::paper(ArchVariant::Dd5);
    let packing = pack(&nl, &arch, &PackOpts::default());
    (nl, packing, arch)
}

fn assert_placement_eq(a: &Placement, b: &Placement, tag: &str) {
    assert_eq!(a.lb_loc, b.lb_loc, "{tag}: lb_loc");
    assert_eq!(a.io_loc, b.io_loc, "{tag}: io_loc");
    assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{tag}: cost");
    assert_eq!(a.est_cpd_ps.to_bits(), b.est_cpd_ps.to_bits(), "{tag}: est_cpd_ps");
    assert_eq!(a.move_stats.proposed, b.move_stats.proposed, "{tag}: proposed");
    assert_eq!(a.move_stats.accepted, b.move_stats.accepted, "{tag}: accepted");
}

/// (a) `Placement` bit-identical for any STA worker count, with the
/// per-sink timing lane on.
#[test]
fn timing_placement_bit_identical_across_sta_jobs() {
    let (nl, packing, arch) = chainy_setup();
    let idx = NetlistIndex::build(&nl);
    let pidx = PackIndex::build(&nl, &packing);
    let mk = |sta_jobs: usize| {
        place_with(
            &nl,
            &packing,
            &arch,
            &PlaceOpts { effort: 0.3, seed: 5, sta_jobs, ..Default::default() },
            &idx,
            &pidx,
        )
        .expect("placement")
    };
    let base = mk(1);
    assert!(base.move_stats.proposed.iter().sum::<usize>() > 0);
    for jobs in [2usize, 8] {
        let p = mk(jobs);
        assert_placement_eq(&base, &p, &format!("sta_jobs={jobs}"));
    }
}

/// (b) Timing-driven placement with a zero-gain lane is the
/// wirelength-only placer, bit for bit: same RNG stream, same deltas,
/// same acceptances, same final cost.
#[test]
fn zero_gain_timing_is_wirelength_only_placer() {
    let (nl, packing, arch) = chainy_setup();
    let wl = place(
        &nl,
        &packing,
        &arch,
        &PlaceOpts { effort: 0.3, seed: 9, timing_driven: false, ..Default::default() },
    )
    .expect("wirelength placement");
    let zg = place(
        &nl,
        &packing,
        &arch,
        &PlaceOpts { effort: 0.3, seed: 9, timing_driven: true, crit_gain: 0.0, ..Default::default() },
    )
    .expect("zero-gain placement");
    assert_placement_eq(&wl, &zg, "zero-gain vs wirelength-only");
}

/// (c) Fixed-seed golden run: every move kind is proposed *and* accepted,
/// chain macros stay vertical columns, and the run reproduces itself.
#[test]
fn golden_run_exercises_every_move_kind() {
    let (nl, packing, arch) = chainy_setup();
    let mk = || {
        place(
            &nl,
            &packing,
            &arch,
            &PlaceOpts { effort: 1.0, seed: 42, ..Default::default() },
        )
        .expect("placement")
    };
    let p = mk();
    let st = &p.move_stats;
    for kind in [MoveKind::Uniform, MoveKind::MacroShift, MoveKind::Median] {
        assert!(
            st.proposed[kind as usize] > 0,
            "{kind:?} never proposed: {:?}",
            st.proposed
        );
        assert!(
            st.accepted[kind as usize] > 0,
            "{kind:?} never accepted: proposed {:?}, accepted {:?}",
            st.proposed,
            st.accepted
        );
    }
    // Uniform swaps stay the bulk of the mix.
    assert!(
        st.proposed[MoveKind::Uniform as usize]
            > st.proposed[MoveKind::MacroShift as usize]
                + st.proposed[MoveKind::Median as usize],
        "diverse moves should not dominate: {:?}",
        st.proposed
    );
    // Macro legality after a macro-move-heavy anneal.
    for m in &packing.chain_macros {
        if m.len() < 2 {
            continue;
        }
        for w in m.windows(2) {
            let a = p.lb_loc[w[0]];
            let b = p.lb_loc[w[1]];
            assert_eq!(a.x, b.x, "macro not in one column");
            assert_eq!(b.y, a.y + 1, "macro not vertically consecutive");
        }
    }
    // Golden: the exact same run again.
    assert_placement_eq(&p, &mk(), "golden rerun");
}

/// (d) `move_mix = 0` proposes uniform swaps only.
#[test]
fn zero_move_mix_is_uniform_only() {
    let (nl, packing, arch) = chainy_setup();
    let p = place(
        &nl,
        &packing,
        &arch,
        &PlaceOpts { effort: 0.3, seed: 3, move_mix: 0.0, ..Default::default() },
    )
    .expect("placement");
    assert!(p.move_stats.proposed[MoveKind::Uniform as usize] > 0);
    assert_eq!(p.move_stats.proposed[MoveKind::MacroShift as usize], 0);
    assert_eq!(p.move_stats.proposed[MoveKind::Median as usize], 0);
}

/// (e) The chained cross-seed feedback loop at the flow layer: two seeds
/// with `--timing-route` on, bit-identical across `route_jobs`, and the
/// second seed really runs under the first seed's achieved-CPD prior
/// (serial reference = the engine-facing `SeedCtx` chain).
#[test]
fn chained_seed_feedback_deterministic_across_route_jobs() {
    use double_duty::flow::{place_route_seed, FlowOpts, SeedCtx};
    let (nl, packing, arch) = chainy_setup();
    let idx = NetlistIndex::build(&nl);
    let pidx = PackIndex::build(&nl, &packing);
    let run_chain = |route_jobs: usize| {
        let opts = FlowOpts {
            seeds: vec![1, 2],
            place_effort: 0.2,
            route_jobs,
            route_timing_weights: true,
            sta_every: 2,
            ..Default::default()
        };
        let mut prior = None;
        let mut out = Vec::new();
        for &seed in &opts.seeds {
            let ctx = SeedCtx { cpd_prior_ps: prior, ..SeedCtx::new(&idx, &pidx) };
            let m = place_route_seed(&nl, &packing, &arch, &opts, seed, &ctx);
            if m.routed_ok {
                prior = Some(m.cpd_ns * 1000.0); // only legal routes feed the chain
            }
            out.push(m);
        }
        out
    };
    let serial = run_chain(1);
    let parallel = run_chain(4);
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(parallel.iter()) {
        assert_eq!(a.cpd_ns.to_bits(), b.cpd_ns.to_bits(), "cpd across route_jobs");
        assert_eq!(a.routed_ok, b.routed_ok);
        assert_eq!(a.channel_util, b.channel_util);
        assert_eq!(a.cpd_trace_ns.len(), b.cpd_trace_ns.len());
        for (x, y) in a.cpd_trace_ns.iter().zip(b.cpd_trace_ns.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "cpd trace across route_jobs");
        }
    }
}
